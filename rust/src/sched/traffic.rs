//! Per-worker traffic derivation for the simulator.
//!
//! For every operator this module answers: *when a worker computes work
//! units `[u0, u1)`, how many bytes does it pull from each NUMA node and
//! how many FLOPs does it execute?* The byte formulas mirror
//! [`crate::ops::cost`]; the node attribution comes from each source
//! tensor's [`Placement`]. Matmul weight rows and attention KV heads use
//! exact row-range attribution (placement alignment is the paper's whole
//! point); secondary streams use proportional spreading.

use crate::graph::{Graph, OpKind};
use crate::numa::cost::Traffic;
use crate::ops::cost as oc;
use crate::tensor::TensorId;

use super::ExecParams;

fn spread_into(t: &mut Traffic, placement: &crate::numa::Placement, bytes: f64) {
    let n = t.bytes.len();
    for (node, b) in placement.spread_bytes(bytes, n) {
        t.add_bytes(node, b);
    }
}

/// Traffic of one worker computing units `[u0, u1)` of tensor `id`.
///
/// `co_readers` = number of workers on the same NUMA node executing
/// this operator. Multi-row (prefill) matmuls amortize the shared
/// activation stream across co-located readers: blocked GEMM fetches X
/// into the node's shared L3 once and every core reuses it, so the
/// DRAM traffic is one stream per node, not one per core. Decode
/// (m = 1) has no reuse dimension and is charged per worker — which is
/// exactly why the paper's TP gain is larger for decode than prefill
/// (§A.2).
#[allow(clippy::too_many_arguments)]
pub fn op_traffic(
    graph: &Graph,
    id: TensorId,
    params: &ExecParams,
    u0: usize,
    u1: usize,
    n_nodes: usize,
    co_readers: usize,
    bcast_amort: f64,
) -> Traffic {
    let mut t = Traffic::new(n_nodes);
    if u0 >= u1 {
        return t;
    }
    let meta = graph.meta(id);
    let src = &meta.src;
    let units = u1 - u0;

    match &meta.op {
        OpKind::Leaf => {}
        OpKind::Embed => {
            let d = meta.row_len();
            let c = oc::embed(d, u0, u1);
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.weight_bytes);
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::RmsNorm { .. } => {
            let d = meta.row_len();
            let c = oc::rmsnorm(d, u0, u1);
            t.flops += c.flops;
            let x = graph.meta(src[0]);
            t.add_placed(&x.placement, u0, u1, x.rows().max(1), d as f64 * 4.0);
            spread_into(&mut t, &graph.meta(src[1]).placement, c.weight_bytes);
            t.add_placed(&meta.placement, u0, u1, meta.rows().max(1), d as f64 * 4.0);
        }
        OpKind::RmsNormHeads { head_dim, .. } => {
            let rows = meta.rows();
            let bytes = (rows * units * head_dim * 4) as f64;
            t.flops += (rows * units * head_dim * 3) as f64;
            spread_into(&mut t, &graph.meta(src[0]).placement, bytes);
            spread_into(&mut t, &meta.placement, bytes);
        }
        OpKind::MatMul => {
            let w = graph.meta(src[1]);
            let x = graph.meta(src[0]);
            let k = w.row_len();
            let n = w.rows();
            let m = x.rows();
            let c = oc::gemm(m, k, u0, u1, w.dtype);
            t.flops += c.flops;
            // exact row-range attribution for the dominant weight stream
            t.add_placed(&w.placement, u0, u1, n, w.dtype.row_bytes(k) as f64);
            // x is read in full by every worker of the stripe; with
            // m > 1 (prefill) the blocked-GEMM stream amortizes over the
            // node's L3; at m = 1 (decode) partial cache dedup applies
            let amortize = if m > 1 {
                co_readers.max(1) as f64
            } else {
                bcast_amort.max(1.0)
            };
            spread_into(&mut t, &x.placement, c.input_bytes / amortize);
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::Rope { head_dim, .. } => {
            let c = oc::rope(meta.rows(), *head_dim, u0, u1);
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.input_bytes);
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::StoreKv { head_dim, .. } => {
            let c = oc::store_kv(graph.meta(src[0]).rows(), *head_dim, u0, u1);
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.input_bytes);
            // writes land in the cache (src[1])
            spread_into(&mut t, &graph.meta(src[1]).placement, c.output_bytes);
        }
        OpKind::Attention { heads, kv_heads, head_dim, max_seq } => {
            let kv_len = params.kv_len().min(*max_seq);
            let c = oc::attention(
                graph.meta(src[0]).rows(), *heads, *kv_heads, *head_dim, kv_len,
                graph.meta(src[1]).dtype, u0, u1,
            );
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.input_bytes);
            // exact attribution of the K/V streams: kv head h occupies
            // row block [h*max_seq, h*max_seq + kv_len) of the cache
            let rep = (heads / kv_heads).max(1);
            let kvh0 = u0 / rep;
            let kvh1 = u1.div_ceil(rep);
            let kc = graph.meta(src[1]);
            let vc = graph.meta(src[2]);
            let cache_rows = kv_heads * max_seq;
            for h in kvh0..kvh1 {
                let r0 = h * max_seq;
                t.add_placed(&kc.placement, r0, r0 + kv_len, cache_rows, (*head_dim * 4) as f64);
                t.add_placed(&vc.placement, r0, r0 + kv_len, cache_rows, (*head_dim * 4) as f64);
            }
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::Silu | OpKind::Copy | OpKind::SliceRow { .. } => {
            let c = oc::elementwise(1, u0, u1);
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.input_bytes);
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::Add | OpKind::Mul | OpKind::SwiGlu => {
            let c = oc::elementwise(2, u0, u1);
            t.flops += c.flops;
            spread_into(&mut t, &graph.meta(src[0]).placement, c.input_bytes / 2.0);
            spread_into(&mut t, &graph.meta(src[1]).placement, c.input_bytes / 2.0);
            spread_into(&mut t, &meta.placement, c.output_bytes);
        }
        OpKind::AddN => {
            let bytes = (units * 4) as f64;
            t.flops += (units * src.len()) as f64;
            for s in src {
                spread_into(&mut t, &graph.meta(*s).placement, bytes);
            }
            spread_into(&mut t, &meta.placement, bytes);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::Placement;
    use crate::tensor::{DType, TensorBundle};

    fn params() -> ExecParams {
        ExecParams::dense(0, 1)
    }

    #[test]
    fn matmul_weight_bytes_go_to_weight_node() {
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![32, 64], Placement::Node(1));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let t = op_traffic(&g, y.single(), &params(), 0, 32, 2, 1, 1.0);
        // weights (36 B/row × 32 rows) on node 1
        assert!(t.bytes[1] >= 32.0 * 36.0);
        // activation (64×4) on node 0
        assert!(t.bytes[0] >= 256.0);
        assert_eq!(t.flops, 2.0 * 64.0 * 32.0);
    }

    #[test]
    fn matmul_row_range_attribution_is_exact() {
        // weights sharded: rows 0..16 node0, 16..32 node1; a worker doing
        // rows 0..16 must read weights ONLY from node 0
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::F32, vec![32, 64], Placement::even_shards(32, 2));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let t = op_traffic(&g, y.single(), &params(), 0, 16, 2, 1, 1.0);
        let weight_bytes_node1: f64 = t.bytes[1];
        // node1 gets only output-spread bytes (output on node 0) → 0
        assert_eq!(weight_bytes_node1, 0.0);
    }

    #[test]
    fn attention_kv_stream_is_charged_to_cache_node() {
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let q = b.leaf("q", DType::F32, vec![1, 64], Placement::Node(0));
        let kc = b.kv_leaf("k", vec![2, 16, 16], Placement::Node(1));
        let vc = b.kv_leaf("v", vec![2, 16, 16], Placement::Node(1));
        let o = b.attention(
            &TensorBundle::one(q),
            &TensorBundle::one(kc),
            &TensorBundle::one(vc),
            4,
            2,
            16,
            16,
        );
        let (g, _) = b.finish();
        let p = ExecParams::dense(7, 1);
        let t = op_traffic(&g, o.single(), &p, 0, 4, 2, 1, 1.0);
        // kv_len = 8; 2 kv heads × 8 pos × 16 dim × 4 B × 2 (K+V)
        let expect = 2.0 * 8.0 * 16.0 * 4.0 * 2.0;
        assert!((t.bytes[1] - expect).abs() < 1e-6, "{} vs {expect}", t.bytes[1]);
    }

    #[test]
    fn partition_halves_traffic() {
        let mut b = GraphBuilder::sim(vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![32, 64], Placement::Node(0));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let full = op_traffic(&g, y.single(), &params(), 0, 32, 1, 1, 1.0);
        let half = op_traffic(&g, y.single(), &params(), 0, 16, 1, 1, 1.0);
        // weight stream halves; activation stream does not
        let w_bytes = 32.0 * 36.0;
        assert!(full.bytes[0] - half.bytes[0] > w_bytes / 2.0 * 0.9);
        assert!(full.flops / half.flops > 1.99 && full.flops / half.flops < 2.01);
    }
}
