//! Graph computation scheduler (paper §2.6, §3.3–3.4).
//!
//! The scheduler walks the static execution list in order. Width-1
//! entries run on the whole pool (every worker computes a slice of the
//! same operator, barrier after each — llama.cpp's model). Width-G
//! entries are TP subgraphs executed by the per-node thread groups
//! under one of two synchronization disciplines (Fig. 9):
//!
//! * **Sync A** — a *global* barrier after every operator: all groups
//!   finish operator `i` before any starts `i+1`;
//! * **Sync B** — *local* barriers inside each group; the global
//!   barrier appears only at the Gather boundary. Groups drift through
//!   their independent streams, hiding stragglers (the paper's
//!   "asynchronous subgraph execution", worth ≈5 tok/s).
//!
//! Two executors share all partitioning code: [`real::RealExecutor`]
//! runs actual kernels on the worker pool; [`sim::SimExecutor`] charges
//! the identical work to the NUMA cost model in virtual time.

pub mod exec_op;
pub mod real;
pub mod sim;
pub mod traffic;

use std::sync::Arc;

pub use real::RealExecutor;
pub use sim::{SimExecutor, SimReport};

/// Synchronization discipline for TP subgraph execution (§3.4, Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Global barrier after every operator.
    SyncA,
    /// Group-local barriers; global only at region boundaries.
    SyncB,
}

/// Per-row sequence view for a continuous-batching pass: each active
/// row belongs to some sequence whose KV lives in its own logical slot
/// of the pooled cache.
///
/// Row `r` is the token at position `pos[r]` of the sequence whose slot
/// starts at cache position `kv_base[r]`; it writes KV slot
/// `kv_base[r] + pos[r]` and attends to `[kv_base[r], kv_base[r] +
/// pos[r]]`. Several rows may belong to the same sequence at
/// consecutive positions (chunked prefill inside a running batch) —
/// StoreKv entries execute before the Attention entry of each layer, so
/// causality holds within a pass.
#[derive(Clone, Debug, Default)]
pub struct BatchView {
    /// First cache position of each row's sequence slot.
    pub kv_base: Vec<usize>,
    /// Position of each row within its sequence.
    pub pos: Vec<usize>,
}

impl BatchView {
    pub fn new(kv_base: Vec<usize>, pos: Vec<usize>) -> Self {
        assert_eq!(kv_base.len(), pos.len(), "batch view row mismatch");
        BatchView { kv_base, pos }
    }

    /// Active rows this pass.
    pub fn rows(&self) -> usize {
        self.pos.len()
    }
}

/// Per-pass runtime parameters (the static graph is position-agnostic).
#[derive(Clone, Debug)]
pub struct ExecParams {
    /// Absolute position of the first row processed this pass (dense
    /// single-sequence passes; batched passes carry per-row positions).
    pub pos: usize,
    /// Rows (tokens) processed this pass: 1 for decode, prompt length
    /// for prefill, active lanes for a batched decode step. Graphs are
    /// built for their maximum row count; ops only compute the first
    /// `rows` rows of a pass.
    pub rows: usize,
    /// Per-row sequence state for multi-sequence (continuous-batching)
    /// passes; `None` for the classic single-sequence graphs.
    pub batch: Option<Arc<BatchView>>,
}

impl ExecParams {
    /// A dense single-sequence pass: `rows` tokens starting at `pos`.
    pub fn dense(pos: usize, rows: usize) -> Self {
        ExecParams { pos, rows, batch: None }
    }

    /// A multi-sequence pass described row-by-row.
    pub fn batched(view: BatchView) -> Self {
        let rows = view.rows();
        ExecParams { pos: 0, rows, batch: Some(Arc::new(view)) }
    }

    /// KV positions live after this pass completes (dense passes; for
    /// batched passes this is a per-sequence notion — see [`BatchView`]).
    pub fn kv_len(&self) -> usize {
        self.pos + self.rows
    }
}

/// Work units an operator partitions across its thread group — the row
/// policy of §2.7 (matmul: weight rows; attention/rope: heads;
/// element-wise: flat elements). Row counts come from tensor shapes,
/// clamped to the pass's active rows so a partially-filled batch graph
/// (and sliced tails like the prefill last-row logits) partitions
/// correctly.
pub fn partition_units(meta: &crate::graph::TensorMeta, params: &ExecParams) -> usize {
    use crate::graph::OpKind::*;
    let act_rows = meta.rows().min(params.rows.max(1));
    match &meta.op {
        Leaf => 0,
        Embed => act_rows,
        RmsNorm { .. } => act_rows,
        RmsNormHeads { heads, .. } => *heads,
        MatMul => meta.row_len(), // output features N
        Rope { heads, .. } => *heads,
        StoreKv { kv_heads, .. } => *kv_heads,
        Attention { heads, .. } => *heads,
        SliceRow { .. } => meta.row_len(),
        Silu | Add | Mul | SwiGlu | Copy | AddN => act_rows * meta.row_len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, TensorMeta};
    use crate::numa::Placement;
    use crate::tensor::DType;

    fn meta(op: OpKind, shape: Vec<usize>) -> TensorMeta {
        TensorMeta {
            name: "t".into(),
            dtype: DType::F32,
            shape,
            op,
            src: vec![],
            placement: Placement::Node(0),
            buf: None,
            group: None,
        }
    }

    #[test]
    fn units_per_op() {
        let p = ExecParams::dense(4, 2);
        assert_eq!(p.kv_len(), 6);
        assert_eq!(partition_units(&meta(OpKind::MatMul, vec![2, 96]), &p), 96);
        let attn = OpKind::Attention { heads: 8, kv_heads: 2, head_dim: 16, max_seq: 64 };
        assert_eq!(partition_units(&meta(attn, vec![2, 128]), &p), 8);
        assert_eq!(partition_units(&meta(OpKind::Add, vec![2, 64]), &p), 128);
        assert_eq!(partition_units(&meta(OpKind::RmsNorm { eps: 1e-6 }, vec![2, 64]), &p), 2);
    }

    #[test]
    fn units_clamp_to_active_rows() {
        // a batch graph built for 8 rows running 3 active lanes
        let p = ExecParams::batched(BatchView::new(vec![0, 64, 128], vec![5, 0, 9]));
        assert_eq!(p.rows, 3);
        assert_eq!(partition_units(&meta(OpKind::Embed, vec![8, 64]), &p), 3);
        assert_eq!(partition_units(&meta(OpKind::Add, vec![8, 64]), &p), 3 * 64);
        assert_eq!(partition_units(&meta(OpKind::RmsNorm { eps: 1e-6 }, vec![8, 64]), &p), 3);
        // matmul still partitions output features, not rows
        assert_eq!(partition_units(&meta(OpKind::MatMul, vec![8, 96]), &p), 96);
    }

    #[test]
    #[should_panic(expected = "row mismatch")]
    fn batch_view_rejects_ragged_rows() {
        BatchView::new(vec![0, 64], vec![1]);
    }
}
