//! Graph computation scheduling (paper §2.6, §3.3–3.4).
//!
//! ## Pass plans and persistent workers
//!
//! A pass is **compiled before it is executed**: [`plan::PassPlan`]
//! lowers the static execution list into a flat step list with the
//! kernel reference, unit count and barrier discipline of every step
//! resolved up front. The real backend then makes **one** pool
//! dispatch per pass ([`crate::threads::ThreadPool::run_pass`]) and
//! the workers walk the plan themselves, synchronizing on spin
//! barriers — no per-operator channel sends, closure boxing or
//! completion-latch round trips on the decode hot path
//! ([`StepReport::dispatches`] records the reduction: ≈`exec.len()`
//! dispatches per pass under the legacy per-op walk, 1 now).
//!
//! Width-1 steps run on the whole pool (every worker computes a slice
//! of the same operator, global barrier after each — llama.cpp's
//! model). Width-G steps are TP subgraphs executed by the per-node
//! thread groups under one of two synchronization disciplines
//! (Fig. 9):
//!
//! * **Sync A** — a *global* barrier after every operator: all groups
//!   finish operator `i` before any starts `i+1`;
//! * **Sync B** — *local* barriers inside each group; the global
//!   barrier appears only at the Gather boundary. Groups drift through
//!   their independent streams, hiding stragglers (the paper's
//!   "asynchronous subgraph execution", worth ≈5 tok/s).
//!
//! ## Kernels and executors
//!
//! Operator semantics live behind the [`crate::ops::kernel::Kernel`]
//! trait — one implementation per `OpKind` (matmul per weight dtype),
//! resolved once at graph build into [`crate::graph::Graph::kernel`].
//! A kernel owns its unit policy (`units`), analytic profile (`cost`),
//! NUMA byte attribution (`traffic`) and real execution (`run`);
//! executors carry no per-op knowledge and never match on `OpKind`.
//!
//! Backends implement the object-safe [`Executor`] trait — a single
//! `run(graph, params) -> StepReport` — so the engine, the serving
//! layer, the report generators and the benches drive
//! [`real::RealExecutor`] (wall-clock kernels on the worker pool),
//! [`sim::SimExecutor`] (the identical work charged to the NUMA cost
//! model in virtual time) and the feature-gated PJRT bridge
//! (`crate::runtime::PjrtExecutor`) through one API. Both native
//! executors consume the **same compiled [`plan::PassPlan`]** — unit
//! accounting (`StepReport::unit_counts`) is computed once at plan
//! compile and reported verbatim by every backend, so a strategy
//! comparison differs only in placement, binding and synchronization.
//!
//! ## Safety contract
//!
//! Real execution writes through raw-pointer arena views held by
//! [`crate::ops::kernel::OpCtx`] — the single place unsafe buffer
//! plumbing lives. Soundness rests on kernels writing only the output
//! region their unit range owns, plus [`debug_check_partition`]
//! asserting (at plan compile, debug builds) that the ranges handed to
//! concurrent workers are disjoint and tile `[0, units)`. Under the
//! single-dispatch model, cross-step ordering comes from the barrier
//! ending each plan step (release/acquire inside
//! [`crate::threads::SpinBarrier::wait`]) instead of the completion
//! latch — see [`plan::PassPlan::run_worker`] for the full argument.

pub mod plan;
pub mod real;
pub mod sim;

use std::sync::Arc;

use crate::graph::Graph;

pub use plan::{PassPlan, PlanPart, PlanStep, StepBarrier};
pub use real::RealExecutor;
pub use sim::{SimExecutor, SimReport};

/// Synchronization discipline for TP subgraph execution (§3.4, Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Global barrier after every operator.
    SyncA,
    /// Group-local barriers; global only at region boundaries.
    SyncB,
}

/// Per-row sequence view for a continuous-batching pass: each active
/// row belongs to some sequence whose KV lives in pages of the paged
/// cache pool named by the row's [`crate::graph::PageTable`].
///
/// Row `r` is the token at logical position `pos[r]` of its sequence;
/// logical position `p` maps to physical cache position
/// `tables[r][p / page_size] · page_size + p % page_size`. The row
/// writes KV at the mapped `pos[r]` and attends to logical positions
/// `[0, pos[r]]`, gathered page by page **in logical order** — the
/// per-row arithmetic order is identical to a contiguous cache, which
/// is what keeps batched decode token-identical to serial. Several
/// rows may belong to the same sequence at consecutive positions
/// (chunked prefill inside a running batch): each row snapshots its
/// own table, and StoreKv entries execute before the Attention entry
/// of each layer, so causality holds within a pass.
#[derive(Clone, Debug)]
pub struct BatchView {
    /// Tokens per physical page.
    pub page_size: usize,
    /// Per-row logical→physical page table (long enough to map
    /// position `pos[r]`).
    pub tables: Vec<crate::graph::PageTable>,
    /// Position of each row within its sequence.
    pub pos: Vec<usize>,
}

impl BatchView {
    pub fn new(page_size: usize, tables: Vec<crate::graph::PageTable>, pos: Vec<usize>) -> Self {
        assert!(page_size >= 1, "batch view needs a positive page size");
        assert_eq!(tables.len(), pos.len(), "batch view row mismatch");
        for (r, (t, &p)) in tables.iter().zip(&pos).enumerate() {
            assert!(t.len() * page_size > p, "row {r}: page table too short for position {p}");
        }
        BatchView { page_size, tables, pos }
    }

    /// Active rows this pass.
    pub fn rows(&self) -> usize {
        self.pos.len()
    }

    /// Physical cache position of row `r`'s token.
    pub fn slot(&self, r: usize) -> usize {
        let p = self.pos[r];
        self.tables[r][p / self.page_size] as usize * self.page_size + p % self.page_size
    }
}

/// Per-pass runtime parameters (the static graph is position-agnostic).
#[derive(Clone, Debug)]
pub struct ExecParams {
    /// Absolute position of the first row processed this pass (dense
    /// single-sequence passes; batched passes carry per-row positions).
    pub pos: usize,
    /// Rows (tokens) processed this pass: 1 for decode, prompt length
    /// for prefill, active lanes for a batched decode step. Graphs are
    /// built for their maximum row count; ops only compute the first
    /// `rows` rows of a pass.
    pub rows: usize,
    /// Per-row sequence state for multi-sequence (continuous-batching)
    /// passes; `None` for the classic single-sequence graphs.
    pub batch: Option<Arc<BatchView>>,
    /// Deterministic per-pass tag: seeds the simulator's op jitter
    /// (pass the decode step index so successive tokens draw fresh
    /// jitter); the real backends ignore it.
    pub seed: u64,
}

impl ExecParams {
    /// A dense single-sequence pass: `rows` tokens starting at `pos`.
    pub fn dense(pos: usize, rows: usize) -> Self {
        ExecParams { pos, rows, batch: None, seed: 0 }
    }

    /// A multi-sequence pass described row-by-row.
    pub fn batched(view: BatchView) -> Self {
        let rows = view.rows();
        ExecParams { pos: 0, rows, batch: Some(Arc::new(view)), seed: 0 }
    }

    /// Tag the pass with a deterministic jitter seed (simulator only).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// KV positions live after this pass completes (dense passes; for
    /// batched passes this is a per-sequence notion — see [`BatchView`]).
    pub fn kv_len(&self) -> usize {
        self.pos + self.rows
    }
}

/// Report of one executed pass, common to every backend.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Pass latency in the backend's time domain: wall-clock seconds
    /// for real/PJRT execution, virtual seconds for the simulator.
    pub elapsed: f64,
    /// Execution-list entries processed.
    pub ops: usize,
    /// Work units of every executed operator, in execution order (TP
    /// entries contribute one count per group) — the partition-parity
    /// surface checked across backends.
    pub unit_counts: Vec<usize>,
    /// Pool dispatches issued for the pass: 1 for every plan-walking
    /// backend (one `run_pass` covering the whole execution list) —
    /// the per-op dispatch tax this counter proves gone (legacy walk:
    /// ≈`exec.len()` per pass).
    pub dispatches: usize,
    /// Whether the pass reused a cached [`PassPlan`] instead of
    /// compiling one (real executor's per-`(graph, rows)` cache;
    /// `false` for backends that compile per pass).
    pub plan_cached: bool,
    /// SIMD tier the vectorized kernels dispatched on this pass
    /// ([`crate::simd::KernelTier::active`] for the native backends;
    /// `Scalar` for PJRT, where native tiers don't apply).
    pub tier: crate::simd::KernelTier,
    /// Simulator detail (`None` for real backends).
    pub sim: Option<SimReport>,
    /// Name of the strategy that executed the pass. Stamped by the
    /// engine (executors don't know their strategy); empty when an
    /// executor is driven directly.
    pub strategy: String,
    /// The auto-tuner's predicted decode-step time (µs) for the chosen
    /// strategy; `None` when the strategy was picked explicitly.
    pub predicted_step_us: Option<f64>,
    /// Provenance of the bandwidth matrix behind the topology the pass
    /// ran against (engine-stamped, like `strategy`).
    pub bandwidth_source: crate::numa::BandwidthSource,
    /// Per-pass tracer rollup (kernel time shares, per-group barrier
    /// skew); `None` unless runtime tracing was enabled
    /// ([`crate::trace::set_enabled`]) on a real-executor pass.
    pub trace: Option<crate::trace::PassRollup>,
}

impl StepReport {
    /// Cross-NUMA traffic share of the pass. Guarded: backends (or
    /// passes) that move no modelled bytes report 0.0, never NaN.
    pub fn remote_fraction(&self) -> f64 {
        self.sim.as_ref().map(SimReport::remote_fraction).unwrap_or(0.0)
    }
}

/// A backend that executes one pass of a static graph.
///
/// Object-safe on purpose: `frontend::Engine` owns a
/// `Box<dyn Executor>`, and the report/bench drivers swap real, sim
/// and PJRT backends behind `&dyn Executor` without parallel code
/// paths.
pub trait Executor {
    /// Short backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Execute one pass of `graph` under `params`.
    fn run(&self, graph: &Arc<Graph>, params: &ExecParams) -> StepReport;
}

/// Debug-build check that [`crate::util::chunk_range`] hands out
/// disjoint, complete unit ranges: worker `i`'s range must end exactly
/// where worker `i+1`'s begins and the union must tile `[0, units)`.
/// Together with the kernels' output-ownership rule this is what makes
/// the raw-pointer arena views of `ops::kernel::OpCtx` sound.
#[inline]
pub fn debug_check_partition(units: usize, parts: usize) {
    #[cfg(debug_assertions)]
    {
        let mut end = 0;
        for i in 0..parts {
            let (a, b) = crate::util::chunk_range(units, parts, i);
            debug_assert!(a == end && b >= a, "unit range overlap at worker {i}");
            end = b;
        }
        debug_assert_eq!(end, units, "unit ranges do not tile [0, units)");
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (units, parts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_params_track_kv_len() {
        let p = ExecParams::dense(4, 2);
        assert_eq!(p.kv_len(), 6);
        assert_eq!(p.seed, 0);
        assert_eq!(p.with_seed(7).seed, 7);
    }

    #[test]
    fn batched_params_count_rows() {
        let view = BatchView::new(64, vec![vec![0], vec![1], vec![2]], vec![5, 0, 9]);
        assert_eq!(view.slot(0), 5);
        assert_eq!(view.slot(1), 64);
        assert_eq!(view.slot(2), 137);
        let p = ExecParams::batched(view);
        assert_eq!(p.rows, 3);
        assert!(p.batch.is_some());
    }

    #[test]
    fn batch_view_maps_through_page_indirection() {
        // logical positions 0..8 at page size 4 through a permuted table
        let view = BatchView::new(4, vec![vec![3, 1]], vec![7]);
        assert_eq!(view.slot(0), 3 * 4 + 3);
        let phys: Vec<usize> =
            (0..8).map(|p| view.tables[0][p / 4] as usize * 4 + p % 4).collect();
        assert_eq!(phys, vec![12, 13, 14, 15, 4, 5, 6, 7]);
    }

    #[test]
    #[should_panic(expected = "row mismatch")]
    fn batch_view_rejects_ragged_rows() {
        BatchView::new(16, vec![vec![0], vec![1]], vec![1]);
    }

    #[test]
    #[should_panic(expected = "page table too short")]
    fn batch_view_rejects_short_tables() {
        BatchView::new(4, vec![vec![0]], vec![4]);
    }

    #[test]
    fn step_report_remote_fraction_is_guarded() {
        // no simulator detail → 0.0, not NaN
        let rep = StepReport::default();
        assert_eq!(rep.remote_fraction(), 0.0);
        // zero-traffic simulator detail → still 0.0
        let rep = StepReport { sim: Some(SimReport::default()), ..Default::default() };
        assert_eq!(rep.remote_fraction(), 0.0);
        assert!(rep.remote_fraction().is_finite());
    }

    #[test]
    fn partition_check_accepts_chunk_range() {
        for units in [0usize, 1, 7, 96, 1000] {
            for parts in [1usize, 2, 3, 48] {
                debug_check_partition(units, parts);
            }
        }
    }
}
