//! Graph computation scheduler (paper §2.6, §3.3–3.4).
//!
//! The scheduler walks the static execution list in order. Width-1
//! entries run on the whole pool (every worker computes a slice of the
//! same operator, barrier after each — llama.cpp's model). Width-G
//! entries are TP subgraphs executed by the per-node thread groups
//! under one of two synchronization disciplines (Fig. 9):
//!
//! * **Sync A** — a *global* barrier after every operator: all groups
//!   finish operator `i` before any starts `i+1`;
//! * **Sync B** — *local* barriers inside each group; the global
//!   barrier appears only at the Gather boundary. Groups drift through
//!   their independent streams, hiding stragglers (the paper's
//!   "asynchronous subgraph execution", worth ≈5 tok/s).
//!
//! Two executors share all partitioning code: [`real::RealExecutor`]
//! runs actual kernels on the worker pool; [`sim::SimExecutor`] charges
//! the identical work to the NUMA cost model in virtual time.

pub mod exec_op;
pub mod real;
pub mod sim;
pub mod traffic;

pub use real::RealExecutor;
pub use sim::{SimExecutor, SimReport};

/// Synchronization discipline for TP subgraph execution (§3.4, Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Global barrier after every operator.
    SyncA,
    /// Group-local barriers; global only at region boundaries.
    SyncB,
}

/// Per-pass runtime parameters (the static graph is position-agnostic).
#[derive(Clone, Copy, Debug)]
pub struct ExecParams {
    /// Absolute position of the first row processed this pass.
    pub pos: usize,
    /// Rows (tokens) processed this pass: 1 for decode, prompt length
    /// for prefill.
    pub rows: usize,
}

impl ExecParams {
    /// KV positions live after this pass completes.
    pub fn kv_len(&self) -> usize {
        self.pos + self.rows
    }
}

/// Work units an operator partitions across its thread group — the row
/// policy of §2.7 (matmul: weight rows; attention/rope: heads;
/// element-wise: flat elements). Row counts come from tensor shapes so
/// sliced tails (prefill last-row logits) partition correctly.
pub fn partition_units(meta: &crate::graph::TensorMeta, _params: &ExecParams) -> usize {
    use crate::graph::OpKind::*;
    match &meta.op {
        Leaf => 0,
        Embed => meta.rows(),
        RmsNorm { .. } => meta.rows(),
        RmsNormHeads { heads, .. } => *heads,
        MatMul => meta.row_len(), // output features N
        Rope { heads, .. } => *heads,
        StoreKv { kv_heads, .. } => *kv_heads,
        Attention { heads, .. } => *heads,
        SliceRow { .. } => meta.row_len(),
        Silu | Add | Mul | SwiGlu | Copy | AddN => meta.numel(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, TensorMeta};
    use crate::numa::Placement;
    use crate::tensor::DType;

    fn meta(op: OpKind, shape: Vec<usize>) -> TensorMeta {
        TensorMeta {
            name: "t".into(),
            dtype: DType::F32,
            shape,
            op,
            src: vec![],
            placement: Placement::Node(0),
            buf: None,
            group: None,
        }
    }

    #[test]
    fn units_per_op() {
        let p = ExecParams { pos: 4, rows: 2 };
        assert_eq!(p.kv_len(), 6);
        assert_eq!(partition_units(&meta(OpKind::MatMul, vec![2, 96]), &p), 96);
        assert_eq!(
            partition_units(&meta(OpKind::Attention { heads: 8, kv_heads: 2, head_dim: 16, max_seq: 64 }, vec![2, 128]), &p),
            8
        );
        assert_eq!(partition_units(&meta(OpKind::Add, vec![2, 64]), &p), 128);
        assert_eq!(partition_units(&meta(OpKind::RmsNorm { eps: 1e-6 }, vec![2, 64]), &p), 2);
    }
}
