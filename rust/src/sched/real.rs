//! Real (wall-clock) graph execution on the worker pool.
//!
//! One pass = one pool dispatch. The execution list is compiled into a
//! [`PassPlan`] (resolved kernels, unit counts and barrier discipline
//! per step), handed to every worker through
//! [`ThreadPool::run_pass`], and the workers stream through it
//! themselves:
//!
//! * width-1 steps → every worker computes a slice of the operator,
//!   then passes the pool-global [`crate::threads::SpinBarrier`];
//! * width-G steps under **Sync A** → each group computes its part,
//!   global barrier after every operator (lockstep);
//! * width-G steps under **Sync B** → group-local barriers between the
//!   operators of a group's stream; the global barrier fires only at
//!   the region end (the Gather boundary).
//!
//! The per-operator mpsc send + `Box<Job>` allocation + latch round
//! trip of the legacy walk are gone from the decode hot path;
//! [`StepReport::dispatches`] records the single dispatch. Per-op work
//! still comes from the kernel resolved at graph build — the plan
//! carries `&'static dyn Kernel` references, and the executor itself
//! has no operator knowledge.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::graph::Graph;
use crate::memory::MemoryPool;
use crate::threads::{Organization, ThreadPool};

use super::{ExecParams, Executor, PassPlan, StepReport, SyncMode};

/// Retained `(graph, rows)` plan shapes. Real engines hold a handful
/// of graphs (decode, prefill, batched decode) and a batched graph
/// sees at most `batch_slots` distinct row counts; the cap is a
/// leak-guard, not a working-set limit (oldest entry evicted).
const PLAN_CACHE_CAP: usize = 32;

/// One cached compiled pass. The `graph` Arc is held strongly, so the
/// pointer identity used as the cache key cannot be recycled while the
/// entry lives.
struct CachedPlan {
    graph: Arc<Graph>,
    rows: usize,
    plan: Arc<PassPlan>,
}

/// Executes graphs on a shared pool/organization.
pub struct RealExecutor {
    pub pool: Arc<MemoryPool>,
    pub threads: Arc<ThreadPool>,
    /// Single-group view (width-1 entries).
    pub org_single: Arc<Organization>,
    /// Per-node view (width-G entries); equals `org_single` when TP is off.
    pub org_tp: Arc<Organization>,
    pub sync: SyncMode,
    /// Compiled-plan cache keyed by `(graph identity, rows)`: unit
    /// counts are position-independent (asserted in debug builds on
    /// every hit), so a plan compiled once serves every later pass of
    /// the same graph and batch shape — dropping even the per-pass
    /// step/part Vec allocations from the decode hot path.
    plans: Mutex<Vec<CachedPlan>>,
    cache_hits: AtomicUsize,
}

impl RealExecutor {
    pub fn new(
        pool: Arc<MemoryPool>,
        threads: Arc<ThreadPool>,
        org_single: Arc<Organization>,
        org_tp: Arc<Organization>,
        sync: SyncMode,
    ) -> Self {
        RealExecutor {
            pool,
            threads,
            org_single,
            org_tp,
            sync,
            plans: Mutex::new(Vec::new()),
            cache_hits: AtomicUsize::new(0),
        }
    }

    /// Cached plans currently retained.
    pub fn plan_cache_len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Passes served from the plan cache since construction.
    pub fn plan_cache_hits(&self) -> usize {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Fetch the compiled plan for `(graph, params.rows)`, compiling
    /// and caching on miss. Returns `(plan, cached)`. Debug builds
    /// recompile on every hit and assert the cached plan is
    /// step-for-step identical to a fresh compile ([`PassPlan::same_as`])
    /// — the continuous proof that unit counts depend only on the
    /// batch shape, never on positions.
    fn plan_for(&self, graph: &Arc<Graph>, params: &ExecParams) -> (Arc<PassPlan>, bool) {
        let n = self.threads.len();
        let mut cache = self.plans.lock().unwrap();
        if let Some(hit) = cache
            .iter()
            .find(|c| Arc::ptr_eq(&c.graph, graph) && c.rows == params.rows)
        {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            #[cfg(debug_assertions)]
            {
                let fresh = PassPlan::compile(graph, params, n, &self.org_tp, self.sync);
                debug_assert!(
                    hit.plan.same_as(&fresh),
                    "cached PassPlan diverged from a fresh compile for rows={}",
                    params.rows
                );
            }
            return (hit.plan.clone(), true);
        }
        let plan = Arc::new(PassPlan::compile(graph, params, n, &self.org_tp, self.sync));
        if cache.len() >= PLAN_CACHE_CAP {
            cache.remove(0);
        }
        cache.push(CachedPlan { graph: graph.clone(), rows: params.rows, plan: plan.clone() });
        (plan, false)
    }
}

impl Executor for RealExecutor {
    fn name(&self) -> &'static str {
        "real"
    }

    /// Run one pass under a single pool dispatch; `elapsed` is host
    /// wall-clock seconds. The compiled plan comes from the
    /// per-`(graph, rows)` cache — only the first pass of each shape
    /// pays the (cheap, linear) compile walk.
    fn run(&self, graph: &Arc<Graph>, params: &ExecParams) -> StepReport {
        let t0 = Instant::now();
        let n = self.threads.len();
        let (plan, plan_cached) = self.plan_for(graph, params);
        let ops = plan.ops();
        let unit_counts = plan.unit_counts.clone();
        let graph = graph.clone();
        let pool = self.pool.clone();
        let org = self.org_tp.clone();
        let params = params.clone();
        let global = self.threads.global_barrier();
        let tracing = crate::trace::enabled();
        let t_ns = if tracing { crate::trace::now_ns() } else { 0 };
        self.threads.run_pass(Arc::new(move |ctx: &crate::threads::WorkerCtx| {
            plan.run_worker(&graph, &pool, &params, &org, n, ctx.worker, &global);
        }));
        // the completion latch inside run_pass ordered every worker's
        // ring writes before this drain
        let trace = if tracing {
            Some(crate::trace::finish_pass(self.threads.trace_pool_id(), t_ns))
        } else {
            None
        };
        StepReport {
            elapsed: t0.elapsed().as_secs_f64(),
            ops,
            unit_counts,
            dispatches: 1,
            plan_cached,
            tier: crate::simd::KernelTier::active(),
            sim: None,
            trace,
            // strategy/bandwidth provenance is engine-stamped
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::{Placement, Topology};
    use crate::tensor::{DType, TensorBundle};

    type TpGraph = (
        Arc<Graph>,
        Arc<MemoryPool>,
        crate::tensor::TensorId,
        crate::tensor::TensorId,
        Vec<crate::tensor::TensorId>,
    );

    /// x[1,4] → scatter(2) → matmul(w_g) → gather == full matmul.
    fn build_tp_graph(pool: MemoryPool) -> TpGraph {
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 4], Placement::Node(0));
        let w0 = b.leaf("w0", DType::F32, vec![2, 4], Placement::Node(0));
        let w1 = b.leaf("w1", DType::F32, vec![2, 4], Placement::Node(1));
        let xs = b.scatter(&TensorBundle::one(x));
        let ys = b.matmul(&xs, &TensorBundle::new(vec![w0, w1]));
        // y parts are [1,2] each; "column concat" via gather-of-padded is
        // modelled as sum of partials in real TP; for the test use gather
        // (sum) of two [1,2] partials
        let z = b.gather(&ys);
        let (g, p) = b.finish();
        (Arc::new(g), Arc::new(p.unwrap()), x, z.single(), vec![w0, w1])
    }

    fn fill(pool: &MemoryPool, graph: &Graph, id: crate::tensor::TensorId, data: &[f32]) {
        let b = graph.buf(id);
        unsafe {
            pool.arena(b.arena).f32s_mut(b.off, data.len()).copy_from_slice(data);
        }
    }

    fn read(pool: &MemoryPool, graph: &Graph, id: crate::tensor::TensorId, n: usize) -> Vec<f32> {
        let b = graph.buf(id);
        unsafe { pool.arena(b.arena).f32s(b.off, n).to_vec() }
    }

    fn executor_for(sync: SyncMode) -> (RealExecutor, TpGraph) {
        let topo = Topology::uniform(2, 2, 100.0, 25.0);
        let cores: Vec<_> = (0..4).map(|i| topo.core(i)).collect();
        let pool_mem = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let built = build_tp_graph(pool_mem);
        let threads = Arc::new(ThreadPool::new(cores.clone()));
        let ex = RealExecutor::new(
            built.1.clone(),
            threads,
            Arc::new(Organization::single(&cores)),
            Arc::new(Organization::by_node(&cores)),
            sync,
        );
        (ex, built)
    }

    fn run_with(sync: SyncMode) -> Vec<f32> {
        let (ex, (graph, pool, x, z, ws)) = executor_for(sync);
        fill(&pool, &graph, x, &[1.0, 2.0, 3.0, 4.0]);
        fill(&pool, &graph, ws[0], &[1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        fill(&pool, &graph, ws[1], &[0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        let rep = ex.run(&graph, &ExecParams::dense(0, 1));
        // scatter + 2 parallel matmul entries... exec entries: scatter,
        // matmul (width 2 each) and the gather
        assert_eq!(rep.ops, graph.exec.len());
        assert!(!rep.unit_counts.is_empty());
        assert!(rep.sim.is_none());
        assert_eq!(rep.dispatches, 1, "whole pass must be one dispatch");
        read(&pool, &graph, z, 2)
    }

    #[test]
    fn tp_sync_a_computes_sum_of_partials() {
        // w0 selects x[0], x[1]; w1 selects x[2], x[3] → sum = [1+3, 2+4]
        assert_eq!(run_with(SyncMode::SyncA), vec![4.0, 6.0]);
    }

    #[test]
    fn tp_sync_b_matches_sync_a() {
        assert_eq!(run_with(SyncMode::SyncB), run_with(SyncMode::SyncA));
    }

    #[test]
    fn one_pool_dispatch_per_pass() {
        let (ex, (graph, pool, x, _z, ws)) = executor_for(SyncMode::SyncB);
        fill(&pool, &graph, x, &[1.0; 4]);
        fill(&pool, &graph, ws[0], &[0.5; 8]);
        fill(&pool, &graph, ws[1], &[0.25; 8]);
        for pass in 1..=10usize {
            let d0 = ex.threads.dispatches();
            let rep = ex.run(&graph, &ExecParams::dense(0, 1));
            assert_eq!(ex.threads.dispatches() - d0, 1, "pass {pass}");
            assert_eq!(rep.dispatches, 1);
        }
    }

    #[test]
    fn traced_pass_records_steps_times_workers_kernel_spans() {
        // serialize against every other test that toggles the
        // process-global tracer flag
        let _g = crate::trace::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let (ex, (graph, pool, x, _z, ws)) = executor_for(SyncMode::SyncB);
        fill(&pool, &graph, x, &[1.0; 4]);
        fill(&pool, &graph, ws[0], &[0.5; 8]);
        fill(&pool, &graph, ws[1], &[0.25; 8]);
        crate::trace::set_enabled(true);
        let rep = ex.run(&graph, &ExecParams::dense(0, 1));
        crate::trace::set_enabled(false);
        let roll = rep.trace.expect("traced pass must carry a rollup");
        assert_eq!(
            roll.kernel_spans,
            graph.exec.len() * ex.threads.len(),
            "one kernel span per plan step per worker (idle workers included)"
        );
        assert!(
            roll.barrier_spans >= ex.threads.len(),
            "every worker parks at least at the region-end global barrier"
        );
        assert!(!roll.kernels.is_empty());
        assert!(roll.skew_us >= 0.0 && roll.global_skew_us >= 0.0);
        // with the flag back off, passes must not attach rollups
        let rep2 = ex.run(&graph, &ExecParams::dense(0, 1));
        assert!(rep2.trace.is_none());
    }

    #[test]
    fn plans_are_cached_per_graph_and_rows() {
        let (ex, (graph, pool, x, z, ws)) = executor_for(SyncMode::SyncB);
        fill(&pool, &graph, x, &[1.0, 2.0, 3.0, 4.0]);
        fill(&pool, &graph, ws[0], &[1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        fill(&pool, &graph, ws[1], &[0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(ex.plan_cache_len(), 0);
        let first = ex.run(&graph, &ExecParams::dense(0, 1));
        assert!(!first.plan_cached, "first pass must compile");
        assert_eq!(ex.plan_cache_len(), 1);
        assert_eq!(ex.plan_cache_hits(), 0);
        // later passes of the same shape hit (position changes don't
        // invalidate — the debug recompile-and-compare assert inside
        // plan_for proves the plans stay identical)
        let again = ex.run(&graph, &ExecParams::dense(0, 1));
        assert!(again.plan_cached);
        assert_eq!(ex.plan_cache_hits(), 1);
        assert_eq!(ex.plan_cache_len(), 1);
        // cached passes still compute the right answer
        assert_eq!(read(&pool, &graph, z, 2), vec![4.0, 6.0]);
        assert_eq!(again.ops, first.ops);
        assert_eq!(again.unit_counts, first.unit_counts);
    }
}
