//! Real (wall-clock) graph execution on the worker pool.
//!
//! Mirrors the simulator's barrier structure exactly:
//!
//! * width-1 entries → whole pool, one dispatch per operator (the
//!   completion latch is the post-op barrier);
//! * width-G runs under **Sync A** → one dispatch per operator, all
//!   groups in lockstep (global barrier semantics);
//! * width-G runs under **Sync B** → one dispatch per *run*: each
//!   worker streams through its group's operators with only the
//!   group-local spin barrier in between.
//!
//! Per-op work comes from the kernel resolved at graph build
//! (`graph.kernel(id)`): workers split `Kernel::units` with
//! [`chunk_range`] and execute their slice through `Kernel::run` over
//! an [`OpCtx`]. The executor itself carries no operator knowledge.

use std::sync::Arc;
use std::time::Instant;

use crate::graph::Graph;
use crate::memory::MemoryPool;
use crate::ops::kernel::OpCtx;
use crate::threads::{Organization, ThreadPool};
use crate::util::chunk_range;

use super::{debug_check_partition, ExecParams, Executor, StepReport, SyncMode};

/// Executes graphs on a shared pool/organization.
pub struct RealExecutor {
    pub pool: Arc<MemoryPool>,
    pub threads: Arc<ThreadPool>,
    /// Single-group view (width-1 entries).
    pub org_single: Arc<Organization>,
    /// Per-node view (width-G entries); equals `org_single` when TP is off.
    pub org_tp: Arc<Organization>,
    pub sync: SyncMode,
}

impl RealExecutor {
    pub fn new(
        pool: Arc<MemoryPool>,
        threads: Arc<ThreadPool>,
        org_single: Arc<Organization>,
        org_tp: Arc<Organization>,
        sync: SyncMode,
    ) -> Self {
        RealExecutor { pool, threads, org_single, org_tp, sync }
    }

    /// Width-1 entry: whole pool partitions one operator. `units` is
    /// the kernel's unit count, computed once by the caller (shared
    /// with the pass report).
    fn run_single(&self, graph: &Arc<Graph>, params: &ExecParams, entry: usize, units: usize) {
        let id = graph.exec[entry].bundle.single();
        let kernel = graph.kernel(id);
        let n = self.threads.len();
        debug_check_partition(units, n);
        let graph = graph.clone();
        let pool = self.pool.clone();
        let params = params.clone();
        self.threads.run_all(Arc::new(move |ctx: &crate::threads::WorkerCtx| {
            let (u0, u1) = chunk_range(units, n, ctx.worker);
            if u0 < u1 {
                let op = OpCtx { graph: &graph, pool: &pool, id, params: &params };
                unsafe { kernel.run(&op, u0, u1) };
            }
        }));
    }

    /// One TP entry, all groups in lockstep (Sync A: the completion
    /// latch across the whole pool is the global barrier).
    fn run_parallel_lockstep(&self, graph: &Arc<Graph>, params: &ExecParams, entry: usize) {
        let graph = graph.clone();
        let pool = self.pool.clone();
        let org = self.org_tp.clone();
        let params = params.clone();
        self.threads.run_all(Arc::new(move |ctx: &crate::threads::WorkerCtx| {
            if let Some((gi, rank)) = org.assignment(ctx.worker) {
                let id = graph.exec[entry].bundle.get(gi);
                let kernel = graph.kernel(id);
                let units = kernel.units(graph.meta(id), &params);
                let size = org.groups[gi].size();
                let (u0, u1) = chunk_range(units, size, rank);
                if u0 < u1 {
                    let op = OpCtx { graph: &graph, pool: &pool, id, params: &params };
                    unsafe { kernel.run(&op, u0, u1) };
                }
            }
        }));
    }

    /// A run `[i, j)` of TP entries under Sync B: each group streams its
    /// own operator sequence with local barriers only.
    fn run_parallel_async(&self, graph: &Arc<Graph>, params: &ExecParams, i: usize, j: usize) {
        let graph = graph.clone();
        let pool = self.pool.clone();
        let org = self.org_tp.clone();
        let params = params.clone();
        self.threads.run_all(Arc::new(move |ctx: &crate::threads::WorkerCtx| {
            if let Some((gi, rank)) = org.assignment(ctx.worker) {
                let group = &org.groups[gi];
                let size = group.size();
                for e in i..j {
                    let id = graph.exec[e].bundle.get(gi);
                    let kernel = graph.kernel(id);
                    let units = kernel.units(graph.meta(id), &params);
                    let (u0, u1) = chunk_range(units, size, rank);
                    if u0 < u1 {
                        let op = OpCtx { graph: &graph, pool: &pool, id, params: &params };
                        unsafe { kernel.run(&op, u0, u1) };
                    }
                    // local barrier: next op of THIS group may depend on
                    // this op; other groups are independent (§3.4)
                    group.barrier().wait();
                }
            }
        }));
    }
}

impl Executor for RealExecutor {
    fn name(&self) -> &'static str {
        "real"
    }

    /// Run the whole execution list for one pass; `elapsed` is host
    /// wall-clock seconds.
    fn run(&self, graph: &Arc<Graph>, params: &ExecParams) -> StepReport {
        let t0 = Instant::now();
        let mut rep = StepReport::default();
        let n_groups = self.org_tp.n_groups();
        let exec = &graph.exec;
        let mut i = 0;
        while i < exec.len() {
            let width = exec[i].bundle.width();
            if width == 1 {
                let id = exec[i].bundle.single();
                let units = graph.kernel(id).units(graph.meta(id), params);
                rep.unit_counts.push(units);
                rep.ops += 1;
                self.run_single(graph, params, i, units);
                i += 1;
            } else {
                assert_eq!(width, n_groups, "entry width {} vs {} groups", width, n_groups);
                // maximal run of parallel entries
                let mut j = i;
                while j < exec.len() && exec[j].bundle.width() == width {
                    j += 1;
                }
                for e in i..j {
                    for gi in 0..width {
                        let id = exec[e].bundle.get(gi);
                        let units = graph.kernel(id).units(graph.meta(id), params);
                        debug_check_partition(units, self.org_tp.groups[gi].size());
                        rep.unit_counts.push(units);
                    }
                    rep.ops += 1;
                }
                match self.sync {
                    SyncMode::SyncA => {
                        for e in i..j {
                            self.run_parallel_lockstep(graph, params, e);
                        }
                    }
                    SyncMode::SyncB => self.run_parallel_async(graph, params, i, j),
                }
                i = j;
            }
        }
        rep.elapsed = t0.elapsed().as_secs_f64();
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::{Placement, Topology};
    use crate::tensor::{DType, TensorBundle};

    type TpGraph = (
        Arc<Graph>,
        Arc<MemoryPool>,
        crate::tensor::TensorId,
        crate::tensor::TensorId,
        Vec<crate::tensor::TensorId>,
    );

    /// x[1,4] → scatter(2) → matmul(w_g) → gather == full matmul.
    fn build_tp_graph(pool: MemoryPool) -> TpGraph {
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 4], Placement::Node(0));
        let w0 = b.leaf("w0", DType::F32, vec![2, 4], Placement::Node(0));
        let w1 = b.leaf("w1", DType::F32, vec![2, 4], Placement::Node(1));
        let xs = b.scatter(&TensorBundle::one(x));
        let ys = b.matmul(&xs, &TensorBundle::new(vec![w0, w1]));
        // y parts are [1,2] each; "column concat" via gather-of-padded is
        // modelled as sum of partials in real TP; for the test use gather
        // (sum) of two [1,2] partials
        let z = b.gather(&ys);
        let (g, p) = b.finish();
        (Arc::new(g), Arc::new(p.unwrap()), x, z.single(), vec![w0, w1])
    }

    fn fill(pool: &MemoryPool, graph: &Graph, id: crate::tensor::TensorId, data: &[f32]) {
        let b = graph.buf(id);
        unsafe {
            pool.arena(b.arena).f32s_mut(b.off, data.len()).copy_from_slice(data);
        }
    }

    fn read(pool: &MemoryPool, graph: &Graph, id: crate::tensor::TensorId, n: usize) -> Vec<f32> {
        let b = graph.buf(id);
        unsafe { pool.arena(b.arena).f32s(b.off, n).to_vec() }
    }

    fn run_with(sync: SyncMode) -> Vec<f32> {
        let topo = Topology::uniform(2, 2, 100.0, 25.0);
        let cores: Vec<_> = (0..4).map(|i| topo.core(i)).collect();
        let pool_mem = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let (graph, pool, x, z, ws) = build_tp_graph(pool_mem);
        fill(&pool, &graph, x, &[1.0, 2.0, 3.0, 4.0]);
        fill(&pool, &graph, ws[0], &[1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        fill(&pool, &graph, ws[1], &[0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        let threads = Arc::new(ThreadPool::new(cores.clone()));
        let ex = RealExecutor::new(
            pool.clone(),
            threads,
            Arc::new(Organization::single(&cores)),
            Arc::new(Organization::by_node(&cores)),
            sync,
        );
        let rep = ex.run(&graph, &ExecParams::dense(0, 1));
        // scatter + 2 parallel matmul entries... exec entries: scatter,
        // matmul (width 2 each) and the gather
        assert_eq!(rep.ops, graph.exec.len());
        assert!(!rep.unit_counts.is_empty());
        assert!(rep.sim.is_none());
        read(&pool, &graph, z, 2)
    }

    #[test]
    fn tp_sync_a_computes_sum_of_partials() {
        // w0 selects x[0], x[1]; w1 selects x[2], x[3] → sum = [1+3, 2+4]
        assert_eq!(run_with(SyncMode::SyncA), vec![4.0, 6.0]);
    }

    #[test]
    fn tp_sync_b_matches_sync_a() {
        assert_eq!(run_with(SyncMode::SyncB), run_with(SyncMode::SyncA));
    }
}
