//! # ArcLight-RS
//!
//! A reproduction of **"ArcLight: A Lightweight LLM Inference
//! Architecture for Many-Core CPUs"** — a lightweight, modular LLM
//! inference engine with NUMA-aware memory management, multi-view
//! thread scheduling and cross-NUMA tensor parallelism, plus the
//! simulated many-core platform the evaluation runs on (see DESIGN.md).

pub mod baseline;
pub mod frontend;
pub mod graph;
pub mod hw;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod ops;
pub mod numa;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod simd;
pub mod tensor;
pub mod threads;
pub mod trace;
pub mod util;
