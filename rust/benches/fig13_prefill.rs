//! Bench: Figure 13 (appendix A.2) — prefill throughput with a
//! 300-token prompt on 2 and 4 NUMA nodes. ArcLight still wins, but by
//! less than in decode: prefill is compute-bound, and TP addresses the
//! memory-access wall.
//!
//!     cargo bench --bench fig13_prefill

use arclight::baseline::Strategy;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::figures::{decode_tok_s, fig13, prefill_tok_s};
use arclight::report::render_table;
use arclight::sched::SyncMode;

fn main() {
    let topo = Topology::kunpeng920();
    let cfg = ModelConfig::qwen3_4b();
    let t0 = std::time::Instant::now();
    for nodes in [2usize, 4] {
        let series = fig13(&cfg, &topo, nodes);
        print!(
            "{}",
            render_table(
                &format!("Figure 13 (N={nodes}): prefill tok/s, prompt 300 (Qwen3-4B Q4_0)"),
                "threads",
                &series
            )
        );
    }

    // the paper's A.2 observation: prefill gain < decode gain
    let d_l = decode_tok_s(&cfg, Strategy::llama_distribute(4), 192, &topo, 300, 128, 4);
    let tp4 = Strategy::arclight_tp(4, SyncMode::SyncB);
    let d_a = decode_tok_s(&cfg, tp4, 192, &topo, 300, 128, 4);
    let p_l = prefill_tok_s(&cfg, Strategy::llama_distribute(4), 192, &topo, 300);
    let p_a = prefill_tok_s(&cfg, Strategy::arclight_tp(4, SyncMode::SyncB), 192, &topo, 300);
    let decode_gain = d_a.tok_per_s / d_l.tok_per_s;
    let prefill_gain = p_a.tok_per_s / p_l.tok_per_s;
    println!(
        "\nTP gain at N=4: decode ×{decode_gain:.2}, prefill ×{prefill_gain:.2} \
         (paper: prefill advantage 'less pronounced')"
    );
    assert!(p_a.tok_per_s > p_l.tok_per_s, "ArcLight should still win prefill");
    assert!(
        prefill_gain < decode_gain,
        "prefill is compute-bound: its TP gain must be smaller"
    );
    println!("sweep time: {:.1} s", t0.elapsed().as_secs_f64());
}
