//! Ablation: Sync A vs Sync B (§3.4, Fig. 9) across node counts and
//! barrier-cost sensitivity.
//!
//! The paper attributes ≈5 tok/s to asynchronous subgraph execution;
//! this ablation shows where that gain comes from (global-barrier
//! latency × the number of TP operators) and how it scales with the
//! cross-node barrier cost.
//!
//!     cargo bench --bench ablation_sync

use arclight::baseline::Strategy;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::figures::decode_tok_s;
use arclight::sched::SyncMode;

fn main() {
    let cfg = ModelConfig::qwen3_4b();
    println!("Sync A (global barrier per op) vs Sync B (local barriers), Qwen3-4B decode\n");
    let cols = ("nodes", "threads", "SyncA tok/s", "SyncB tok/s", "B−A tok/s");
    println!("{:>6} {:>9} {:>12} {:>12} {:>12}", cols.0, cols.1, cols.2, cols.3, cols.4);
    for nodes in [2usize, 4] {
        let threads = nodes * 48;
        let topo = Topology::kunpeng920();
        let sync_a = Strategy::arclight_tp(nodes, SyncMode::SyncA);
        let sync_b = Strategy::arclight_tp(nodes, SyncMode::SyncB);
        let a = decode_tok_s(&cfg, sync_a, threads, &topo, 15, 256, 4);
        let b = decode_tok_s(&cfg, sync_b, threads, &topo, 15, 256, 4);
        println!(
            "{:>6} {:>9} {:>12.1} {:>12.1} {:>12.1}",
            nodes, threads, a.tok_per_s, b.tok_per_s, b.tok_per_s - a.tok_per_s
        );
        assert!(b.tok_per_s >= a.tok_per_s);
    }

    println!("\nsensitivity to the cross-node barrier cost (N=4, 192 threads):");
    let cols = ("barrier/node (µs)", "SyncA tok/s", "SyncB tok/s", "B−A tok/s");
    println!("{:>18} {:>12} {:>12} {:>12}", cols.0, cols.1, cols.2, cols.3);
    for per_node_us in [0.5f64, 2.0, 8.0] {
        let mut topo = Topology::kunpeng920();
        topo.barrier_per_node = per_node_us * 1e-6;
        let sync_a = Strategy::arclight_tp(4, SyncMode::SyncA);
        let sync_b = Strategy::arclight_tp(4, SyncMode::SyncB);
        let a = decode_tok_s(&cfg, sync_a, 192, &topo, 15, 256, 4);
        let b = decode_tok_s(&cfg, sync_b, 192, &topo, 15, 256, 4);
        println!(
            "{:>18} {:>12.1} {:>12.1} {:>12.1}",
            per_node_us, a.tok_per_s, b.tok_per_s, b.tok_per_s - a.tok_per_s
        );
    }
    println!("\nSync B's advantage grows with cross-node barrier latency —");
    println!("async subgraphs remove the per-operator global barrier from the critical path.");
}
