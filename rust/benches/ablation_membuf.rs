//! Ablation: double-buffered activations (§2.3, Fig. 4) vs linear
//! per-tensor allocation — the activation-memory footprint across
//! model depths, plus a real-build verification on the tiny and small
//! models.
//!
//!     cargo bench --bench ablation_membuf

use arclight::memory::{ActivationPlanner, PlanMode};
use arclight::model::{BuildSpec, ModelConfig, ModelGraphs};

fn planned_footprint(mode: PlanMode, layers: usize, per_layer_bytes: usize) -> usize {
    let mut p = ActivationPlanner::new(mode);
    for l in 0..layers {
        p.enter_layer(l);
        for _ in 0..16 {
            p.note_alloc(per_layer_bytes / 16);
        }
    }
    p.footprint()
}

fn main() {
    println!("activation footprint: double-buffered (ArcLight, Fig. 4) vs linear\n");
    println!("{:>8} {:>16} {:>16} {:>8}", "layers", "double-buf (MB)", "linear (MB)", "saving");
    let per_layer = 4 << 20; // 4 MB of activations per layer
    for layers in [8usize, 16, 36, 64] {
        let db = planned_footprint(PlanMode::DoubleBuffered, layers, per_layer);
        let lin = planned_footprint(PlanMode::Linear, layers, per_layer);
        println!(
            "{:>8} {:>16.1} {:>16.1} {:>7.1}x",
            layers,
            db as f64 / 1e6,
            lin as f64 / 1e6,
            lin as f64 / db as f64
        );
        assert_eq!(lin / db, layers / 2, "double buffering must be depth-invariant");
    }

    println!("\nreal graph builds (measured peak activation bytes):");
    for (name, cfg) in [("tiny", ModelConfig::tiny()), ("small-25m", ModelConfig::small_25m())] {
        let t0 = std::time::Instant::now();
        let db = ModelGraphs::build(BuildSpec::arclight(cfg.clone(), 1));
        let mut lin_spec = BuildSpec::arclight(cfg.clone(), 1);
        lin_spec.plan_mode = PlanMode::Linear;
        // linear mode needs a bigger pool: build sim-only for footprint
        let _ = lin_spec;
        println!(
            "  {name:10} double-buffered peak: {:>9.1} KB (built in {:.0} ms)",
            db.act_footprint as f64 / 1e3,
            t0.elapsed().as_secs_f64() * 1e3
        );
        // depth-invariance on the real builder: the 8-layer model's
        // footprint must be comparable to a 2-layer variant's, not 4x
        let mut two = cfg.clone();
        two.n_layers = 2;
        let db2 = ModelGraphs::build(BuildSpec::arclight(two, 1));
        let ratio = db.act_footprint as f64 / db2.act_footprint as f64;
        println!(
            "  {name:10} vs 2-layer variant: {ratio:.2}x footprint for {}x depth",
            cfg.n_layers / 2
        );
        assert!(ratio < 1.6, "double buffering must keep activations depth-invariant");
    }
}
