//! Real wall-clock microbenchmarks of the operator hot paths (the §Perf
//! targets): Q4_0 GEMV/GEMM, the scheduler's dispatch overhead (per-op
//! jobs vs one compiled pass), fused attention, RMSNorm, and the
//! end-to-end decode step of the real engine on the small model —
//! single-sequence and continuous-batched. The JSON report carries
//! `dispatches_per_token` for the perf trajectory, plus per-kernel
//! achieved GB/s: each kernel row pairs its measured p50 with the
//! analytic bytes-touched figure from `ops::cost`, read against one
//! NUMA node's local bandwidth (`roofline_frac`; compare with
//! `arclight topo`).
//!
//! These are host-machine numbers (1 core in this environment), used for
//! the optimization loop — the paper-figure numbers come from the
//! simulated testbed instead.
//!
//!     cargo bench --bench ops_hotpath [-- --quick] [-- --json <path>]
//!         [-- --pin] [-- --tier scalar|avx2|avx512|neon]
//!         [-- --strategy arclight|llama-isolate|auto] [-- --cache <path>]
//!         [-- --trace <path>]
//!
//! `--quick` shrinks sizes/iterations for the CI bench-smoke leg;
//! `--json <path>` writes the measured per-iteration seconds as a JSON
//! report (the perf-trajectory artifact); `--pin` runs the end-to-end
//! engines on the detected host platform with pinned workers and
//! first-touch arenas (degrades to simulated when unavailable);
//! `--tier` forces the SIMD kernel tier (default: auto-detect). The
//! Q4_0 GEMV section always benches the scalar oracle next to the
//! active tier so the SIMD speedup is visible in one run.
//!
//! `--strategy auto` lets the cost-model auto-tuner pick the
//! end-to-end engines' strategy; `--cache` points at the calibration
//! cache (`arclight calibrate`), whose measured matrix — when its
//! fingerprint matches a detected host platform — replaces the SLIT
//! placeholder lowering. The JSON report records `strategy_chosen`,
//! `predicted_step_us` and `bandwidth_source` so roofline fractions
//! are never silently read against the placeholder scale.
//!
//! `--trace <path>` turns the runtime tracer on: the pass-dispatch
//! section is measured once with tracing off and once on (the
//! disabled-path overhead check — `pass_us` vs `pass_us_traced` in the
//! JSON), the end-to-end sections then run traced so the report gains
//! `barrier_skew_us` and a `drift` block, and a Chrome `trace_event`
//! JSON of the collected spans is written to `<path>` at exit.

use std::sync::Arc;
use std::time::Instant;

use arclight::baseline::{tune, Strategy};
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::hw::{membind, Platform};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::ops;
use arclight::ops::cost;
use arclight::quant::quantize_matrix_q4_0;
use arclight::report::BenchRow;
use arclight::simd::KernelTier;
use arclight::tensor::DType;
use arclight::threads::{ThreadPool, WorkerCtx};
use arclight::util::json::{obj, Json};
use arclight::util::stats::{fmt_duration, Summary};
use arclight::util::Rng;

/// warmup + timed iterations; returns per-iteration seconds and logs
/// the row — with its `ops::cost` traffic model, when one exists —
/// into `report`.
fn bench<F: FnMut()>(
    report: &mut Vec<BenchRow>,
    name: &str,
    iters: usize,
    bytes: Option<f64>,
    tier: &'static str,
    mut f: F,
) -> f64 {
    for _ in 0..3 {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    let p50 = s.p50();
    println!("{name:42} {:>12}/iter  (min {:>12})", fmt_duration(p50), fmt_duration(s.min()));
    report.push(BenchRow { name: name.to_string(), p50_s: p50, bytes_touched: bytes, tier });
    p50
}

/// Achieved-GB/s line for the last benched row, against one node's
/// local memory bandwidth.
fn print_gbs(row: &BenchRow, node_bw: f64) {
    if let Some(gbs) = row.gbs() {
        let frac = if node_bw > 0.0 { gbs * 1e9 / node_bw * 100.0 } else { 0.0 };
        println!("{:42} {gbs:>8.2} GB/s achieved ({frac:.0}% of node bw)", "");
    }
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v, 1.0);
    v
}

fn engine_opts(
    strategy: Strategy,
    base_node: usize,
    platform: &Platform,
    pin: bool,
    threads: usize,
    batch_slots: usize,
) -> EngineOptions {
    EngineOptions {
        strategy,
        threads,
        platform: platform.clone(),
        prefill_rows: None,
        seed: 0,
        batch_slots,
        pin,
        page_size: 16,
        kv_pages: None,
        base_node,
    }
}

/// `--strategy` resolution for the end-to-end sections: explicit
/// names, or `auto` through the cost-model tuner (returns the winner's
/// placement and predicted step µs).
fn resolve_strategy(
    name: &str,
    cfg: &ModelConfig,
    platform: &Platform,
    threads: usize,
) -> (Strategy, usize, Option<f64>) {
    match name {
        "auto" => {
            let topo = platform.topology();
            let t = tune::auto_select(cfg, topo, threads, 0, topo.n_nodes())
                .expect("auto-tune: no strategy fits");
            (t.best.strategy, t.best.base_node, Some(t.best.predicted_us))
        }
        "arclight" => (Strategy::arclight_single(), 0, None),
        "llama-isolate" => (Strategy::llama_isolate(), 0, None),
        other => {
            eprintln!("unknown --strategy '{other}' (arclight|llama-isolate|auto)");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let pin = args.iter().any(|a| a == "--pin");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let trace_path = args
        .iter()
        .position(|a| a == "--trace")
        .and_then(|i| args.get(i + 1))
        .cloned();
    if let Some(name) = args.iter().position(|a| a == "--tier").and_then(|i| args.get(i + 1)) {
        if name != "auto" {
            let t = KernelTier::parse(name).unwrap_or_else(|| {
                eprintln!("unknown tier '{name}' (scalar|avx2|avx512|neon|auto)");
                std::process::exit(2);
            });
            if let Err(e) = KernelTier::set_active(t) {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
    let tier = KernelTier::active();
    // worker threads the end-to-end engine sections below actually use
    let max_engine_threads = if quick { 2 } else { 4 };
    let strategy_arg = args
        .iter()
        .position(|a| a == "--strategy")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "arclight".to_string());
    let cache = args
        .iter()
        .position(|a| a == "--cache")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from)
        .unwrap_or_else(arclight::hw::bench::default_cache_path);
    let platform = if pin {
        let (p, note) = Platform::host_with_membind(max_engine_threads);
        if let Some(why) = note {
            println!("--pin requested but {why}; running simulated");
        }
        p
    } else {
        Platform::simulated()
    };
    // a fingerprint-matched calibration upgrades a host platform's
    // lowering to the measured matrix (no-op on simulated)
    let platform = platform.with_cached_calibration(&cache);
    // roofline reference: one node's local memory bandwidth
    let node_bw = platform.topology().bandwidth(0, 0);
    let mut pinned_workers = 0usize;
    let mut report: Vec<BenchRow> = Vec::new();
    let rep = &mut report;

    println!(
        "== operator hot paths (host wall-clock{}, {tier} tier, node bw {:.0} GB/s) ==\n",
        if quick { ", quick mode" } else { "" },
        node_bw / 1e9
    );

    // --- Q4_0 GEMV: the decode inner loop -----------------------------------
    let (n, k) = if quick { (512usize, 512usize) } else { (2048usize, 2048usize) };
    let gemv_iters = if quick { 5 } else { 20 };
    let w = rand_vec(n * k, 1);
    let wq = quantize_matrix_q4_0(&w, n, k);
    let x = rand_vec(k, 2);
    let mut out = vec![0.0f32; n];
    let gemv_bytes = cost::gemm(1, k, 0, n, DType::Q4_0).total_bytes();
    let name_q4 = format!("q4_0 gemv {n}x{k}");
    let t = bench(rep, &name_q4, gemv_iters, Some(gemv_bytes), tier.name(), || {
        ops::gemm::gemm_q4_0_t(tier, &x, &wq, &mut out, 1, k, n, 0, n);
    });
    print_gbs(rep.last().unwrap(), node_bw);
    let gflops = 2.0 * (n * k) as f64 / t / 1e9;
    println!("{:42} {gflops:>8.2} GFLOP/s", "");
    // the scalar oracle next to the active tier: the SIMD speedup
    if tier != KernelTier::Scalar {
        let name = format!("q4_0 gemv {n}x{k} (scalar oracle)");
        let ts = bench(rep, &name, gemv_iters, Some(gemv_bytes), "scalar", || {
            ops::gemm::gemm_q4_0_t(KernelTier::Scalar, &x, &wq, &mut out, 1, k, n, 0, n);
        });
        println!("{:42} {tier} speedup over scalar: {:.2}x", "", ts / t);
    }

    // --- f32 GEMV reference --------------------------------------------------
    let mut out_f = vec![0.0f32; n];
    let f32_bytes = cost::gemm(1, k, 0, n, DType::F32).total_bytes();
    let name_f32 = format!("f32 gemv {n}x{k}");
    let tf = bench(rep, &name_f32, gemv_iters, Some(f32_bytes), tier.name(), || {
        ops::gemm::gemm_f32_t(tier, &x, &w, &mut out_f, 1, k, n, 0, n);
    });
    print_gbs(rep.last().unwrap(), node_bw);
    println!("{:42} q4/f32 time ratio: {:.2} (q4 moves 7.1x fewer bytes)", "", t / tf);

    // --- batched GEMM (m = 8): the continuous-batching decode shape ----------
    let m = 8usize;
    let xm = rand_vec(m * k, 3);
    let mut outm = vec![0.0f32; m * n];
    let gemm_bytes = cost::gemm(m, k, 0, n, DType::Q4_0).total_bytes();
    let name_m = format!("q4_0 gemm {m}x{k} · {n}x{k}ᵀ");
    let tm = bench(rep, &name_m, gemv_iters.max(10), Some(gemm_bytes), tier.name(), || {
        ops::gemm::gemm_q4_0_t(tier, &xm, &wq, &mut outm, m, k, n, 0, n);
    });
    print_gbs(rep.last().unwrap(), node_bw);
    println!(
        "{:42} {:>8.2} GFLOP/s, {:.2}x the GEMV time for {m}x the tokens",
        "",
        2.0 * (m * n * k) as f64 / tm / 1e9,
        tm / t
    );

    // --- dispatch overhead: per-op jobs vs one compiled pass -----------------
    // The §3.3 scheduling tax in isolation: N empty "operators" run
    // either as N boxed-job dispatches (send + alloc + latch each, the
    // legacy walk) or as ONE run_pass dispatch whose workers walk N
    // barrier-separated phases themselves (the PassPlan model).
    let mut pass_us = 0.0f64;
    let mut pass_us_traced: Option<f64> = None;
    {
        let workers = 4usize;
        let n_ops = if quick { 64usize } else { 256usize };
        let disp_iters = if quick { 5 } else { 20 };
        let topo = Topology::kunpeng920();
        let cores: Vec<_> = (0..workers).map(|i| topo.core(i)).collect();
        let pool = ThreadPool::new(cores);
        let name_old = format!("dispatch {n_ops} empty ops, per-op path");
        let t_old = bench(rep, &name_old, disp_iters, None, tier.name(), || {
            for _ in 0..n_ops {
                pool.run_all(Arc::new(|_: &WorkerCtx| {}));
            }
        });
        let gb = pool.global_barrier();
        let name_new = format!("dispatch {n_ops} empty ops, pass path");
        let t_new = bench(rep, &name_new, disp_iters, None, tier.name(), || {
            let gb = gb.clone();
            pool.run_pass(Arc::new(move |_: &WorkerCtx| {
                for _ in 0..n_ops {
                    gb.wait();
                }
            }));
        });
        println!(
            "{:42} {:.2}x dispatch-tax reduction ({} dispatches -> 1 per pass)",
            "",
            t_old / t_new,
            n_ops
        );
        pass_us = t_new * 1e6;
        // the same pass with the tracer live: every barrier arrival
        // times itself and records a span, so the traced/untraced
        // ratio bounds the enabled-path cost per wait (the untraced
        // run above already exercised the disabled path — one relaxed
        // load per arrival)
        if trace_path.is_some() {
            arclight::trace::set_enabled(true);
            let name_tr = format!("dispatch {n_ops} empty ops, pass path (traced)");
            let t_tr = bench(rep, &name_tr, disp_iters, None, tier.name(), || {
                let gb = gb.clone();
                pool.run_pass(Arc::new(move |_: &WorkerCtx| {
                    for _ in 0..n_ops {
                        gb.wait();
                    }
                }));
            });
            pass_us_traced = Some(t_tr * 1e6);
            println!("{:42} traced/untraced pass ratio: {:.2}x", "", t_tr / t_new);
        }
    }

    // --- fused attention over the KV cache -----------------------------------
    let (heads, kvh, hd) = (16usize, 8usize, 64usize);
    let (max_seq, kv_len) = if quick { (128usize, 96usize) } else { (512usize, 384usize) };
    let q = rand_vec(heads * hd, 4);
    let kc = rand_vec(kvh * max_seq * hd, 5);
    let vc = rand_vec(kvh * max_seq * hd, 6);
    let mut ao = vec![0.0f32; heads * hd];
    // the traffic model the --quick JSON used to omit for attention
    let attn_bytes = cost::attention(1, heads, kvh, hd, kv_len, DType::F32, 0, heads).total_bytes();
    let name_a = format!("attention decode H={heads} kv_len={kv_len}");
    bench(rep, &name_a, gemv_iters, Some(attn_bytes), tier.name(), || {
        let p0 = kv_len - 1;
        ops::attention::attention_t(
            tier, &q, &kc, &vc, &mut ao, 1, heads, kvh, hd, max_seq, p0, 0, heads,
        );
    });
    print_gbs(rep.last().unwrap(), node_bw);

    // --- RMSNorm -------------------------------------------------------------
    let d = 2048usize;
    let xr = rand_vec(d, 7);
    let g = rand_vec(d, 8);
    let mut outn = vec![0.0f32; d];
    let norm_bytes = cost::rmsnorm(d, 0, 1).total_bytes();
    let norm_iters = if quick { 10 } else { 50 };
    bench(rep, &format!("rmsnorm d={d}"), norm_iters, Some(norm_bytes), tier.name(), || {
        ops::norm::rmsnorm_t(tier, &xr, &g, &mut outn, d, 1e-6, 0, 1);
    });
    print_gbs(rep.last().unwrap(), node_bw);

    // --- end-to-end decode step (real engine, small model) -------------------
    println!("\n== end-to-end decode (small-25m, real engine) ==\n");
    let cfg = if quick { ModelConfig::tiny() } else { ModelConfig::small_25m() };
    let thread_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    let step_iters = if quick { 4 } else { 12 };
    // dispatch tax of a real decode pass: pool dispatches per decoded
    // token (1 under the compiled-pass scheduler)
    let mut dispatches_per_token = 0.0f64;
    let mut strategy_chosen = String::from("arclight");
    let mut predicted_step_us: Option<f64> = None;
    // straggler/drift gauges off the last traced decode engine
    let mut barrier_skew_us: Option<f64> = None;
    let mut drift_measured_us: Option<f64> = None;
    let mut drift_ratio: Option<f64> = None;
    let mut retune_recommended = false;
    for &threads in thread_counts {
        let (strat, base, predicted) = resolve_strategy(&strategy_arg, &cfg, &platform, threads);
        strategy_chosen = strat.name();
        predicted_step_us = predicted;
        if let Some(us) = predicted {
            println!("auto strategy @ {threads} thread(s): {strategy_chosen} (predicted {us:.1} µs/step)");
        }
        let mut engine = Engine::new_synthetic(
            cfg.clone(),
            &engine_opts(strat, base, &platform, pin, threads, 1),
        )
        .unwrap();
        pinned_workers = pinned_workers.max(engine.pinned_workers());
        engine.prefill(&[1, 2, 3, 4]);
        let horizon = cfg.max_seq - 24;
        let mut step = 0usize;
        let name_e = format!("decode step, {threads} worker(s)");
        let t = bench(rep, &name_e, step_iters, None, tier.name(), || {
            let logits = engine.decode_step((step % 200) as i32 + 5);
            step += 1;
            std::hint::black_box(&logits);
            if engine.position() > horizon {
                engine.reset();
                engine.prefill(&[1, 2, 3, 4]);
            }
        });
        dispatches_per_token = engine
            .last_step_report()
            .map(|r| r.dispatches as f64)
            .unwrap_or(0.0);
        barrier_skew_us = engine
            .last_step_report()
            .and_then(|r| r.trace.as_ref().map(|t| t.skew_us))
            .or(barrier_skew_us);
        drift_measured_us = engine.step_ewma_us();
        drift_ratio = engine.drift_ratio();
        retune_recommended = engine.retune_recommended();
        println!(
            "{:42} {:>8.1} tok/s ({} dispatch/token)",
            "",
            1.0 / t,
            dispatches_per_token
        );
    }

    // --- batched decode step (continuous batching, 4 live sequences) ---------
    {
        let slots = 4usize;
        let (strat, base, _) = resolve_strategy(&strategy_arg, &cfg, &platform, 2);
        let mut engine = Engine::new_synthetic(
            cfg.clone(),
            &engine_opts(strat, base, &platform, pin, 2, slots),
        )
        .unwrap();
        let budget = cfg.max_seq;
        let mut seqs: Vec<_> = (0..slots).map(|_| engine.seq_start(budget).unwrap()).collect();
        let horizon = cfg.max_seq - 24;
        let mut step = 0usize;
        let name_b = format!("batched decode step, {slots} lanes");
        let t = bench(rep, &name_b, step_iters, None, tier.name(), || {
            let lanes: Vec<_> = seqs.iter().map(|s| (s, (step % 200) as i32 + 5)).collect();
            let logits = engine.step_batch(&lanes);
            drop(lanes); // release the seq borrows before the reset check
            step += 1;
            std::hint::black_box(&logits);
            if seqs.iter().any(|s| engine.seq_pos(s) > horizon) {
                seqs.clear(); // RAII: drops return every page to the arena
                engine.reset();
                seqs = (0..slots).map(|_| engine.seq_start(budget).unwrap()).collect();
            }
        });
        println!("{:42} {:>8.1} tok/s aggregate", "", slots as f64 / t);
    }

    // --- generation sanity ----------------------------------------------------
    let (strat, base, _) = resolve_strategy(&strategy_arg, &cfg, &platform, 2);
    let mut engine =
        Engine::new_synthetic(cfg, &engine_opts(strat, base, &platform, pin, 2, 1)).unwrap();
    let res = engine.generate(&[1, 2, 3, 4, 5], if quick { 8 } else { 32 }, &Sampler::greedy());
    println!("\ngenerate {} tokens: {:.1} tok/s decode", res.decode_tokens, res.decode_tok_per_s());

    if let Some(path) = json_path {
        let entries: Vec<Json> = report.iter().map(|row| row.to_json(node_bw)).collect();
        let j = obj(vec![
            ("benchmark", "ops_hotpath".into()),
            ("quick", quick.into()),
            ("platform", platform.name().into()),
            ("strategy_chosen", strategy_chosen.clone().into()),
            ("predicted_step_us", predicted_step_us.map(Json::from).unwrap_or(Json::Null)),
            ("bandwidth_source", platform.topology().bw_source.name().into()),
            ("tier", tier.name().into()),
            ("node_bandwidth_gb", (node_bw / 1e9).into()),
            ("pinned_workers", pinned_workers.into()),
            ("node_local_bytes", (membind::node_local_bytes() as usize).into()),
            ("dispatches_per_token", dispatches_per_token.into()),
            ("traced", trace_path.is_some().into()),
            ("pass_us", pass_us.into()),
            ("pass_us_traced", pass_us_traced.map(Json::from).unwrap_or(Json::Null)),
            ("barrier_skew_us", barrier_skew_us.map(Json::from).unwrap_or(Json::Null)),
            (
                "drift",
                obj(vec![
                    (
                        "measured_step_us",
                        drift_measured_us.map(Json::from).unwrap_or(Json::Null),
                    ),
                    (
                        "predicted_step_us",
                        predicted_step_us.map(Json::from).unwrap_or(Json::Null),
                    ),
                    ("ratio", drift_ratio.map(Json::from).unwrap_or(Json::Null)),
                    ("retune_recommended", retune_recommended.into()),
                ]),
            ),
            ("results", Json::Arr(entries)),
        ]);
        if let Some(parent) = std::path::Path::new(&path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(&path, j.to_string()).expect("write json report");
        println!("wrote report to {path}");
    }

    if let Some(path) = &trace_path {
        arclight::trace::export_chrome(std::path::Path::new(path)).expect("write chrome trace");
        println!(
            "wrote chrome trace ({} spans collected, {} dropped) to {path}",
            arclight::trace::collected_spans(),
            arclight::trace::dropped_spans()
        );
    }
}
