//! Real wall-clock microbenchmarks of the operator hot paths (the §Perf
//! targets): Q4_0 GEMV/GEMM, fused attention, RMSNorm, and the end-to-end
//! decode step of the real engine on the small model.
//!
//! These are host-machine numbers (1 core in this environment), used for
//! the optimization loop — the paper-figure numbers come from the
//! simulated testbed instead.
//!
//!     cargo bench --bench ops_hotpath

use std::time::Instant;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::ops;
use arclight::quant::quantize_matrix_q4_0;
use arclight::util::stats::{fmt_duration, Summary};
use arclight::util::Rng;

/// warmup + timed iterations; returns per-iteration seconds.
fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..3 {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    let p50 = s.p50();
    println!("{name:42} {:>12}/iter  (min {:>12})", fmt_duration(p50), fmt_duration(s.min()));
    p50
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v, 1.0);
    v
}

fn main() {
    println!("== operator hot paths (host wall-clock) ==\n");

    // --- Q4_0 GEMV: the decode inner loop -----------------------------------
    let (n, k) = (2048usize, 2048usize);
    let w = rand_vec(n * k, 1);
    let wq = quantize_matrix_q4_0(&w, n, k);
    let x = rand_vec(k, 2);
    let mut out = vec![0.0f32; n];
    let t = bench(&format!("q4_0 gemv {n}x{k}"), 20, || {
        ops::gemm::gemm_q4_0(&x, &wq, &mut out, 1, k, n, 0, n);
    });
    let bytes = wq.len() as f64;
    let gbs = bytes / t / 1e9;
    let gflops = 2.0 * (n * k) as f64 / t / 1e9;
    println!("{:42} {gbs:>8.2} GB/s weight stream, {gflops:>6.2} GFLOP/s", "");

    // --- f32 GEMV reference --------------------------------------------------
    let mut out_f = vec![0.0f32; n];
    let tf = bench(&format!("f32 gemv {n}x{k}"), 20, || {
        ops::gemm::gemm_f32(&x, &w, &mut out_f, 1, k, n, 0, n);
    });
    println!("{:42} q4/f32 time ratio: {:.2} (q4 moves 7.1x fewer bytes)", "", t / tf);

    // --- prefill GEMM (m = 16) ----------------------------------------------
    let m = 16usize;
    let xm = rand_vec(m * k, 3);
    let mut outm = vec![0.0f32; m * n];
    let tm = bench(&format!("q4_0 gemm {m}x{k} · {n}x{k}ᵀ"), 10, || {
        ops::gemm::gemm_q4_0(&xm, &wq, &mut outm, m, k, n, 0, n);
    });
    println!("{:42} {:>8.2} GFLOP/s", "", 2.0 * (m * n * k) as f64 / tm / 1e9);

    // --- fused attention over the KV cache -----------------------------------
    let (heads, kvh, hd, max_seq, kv_len) = (16usize, 8usize, 64usize, 512usize, 384usize);
    let q = rand_vec(heads * hd, 4);
    let kc = rand_vec(kvh * max_seq * hd, 5);
    let vc = rand_vec(kvh * max_seq * hd, 6);
    let mut ao = vec![0.0f32; heads * hd];
    bench(&format!("attention decode H={heads} kv_len={kv_len}"), 20, || {
        ops::attention::attention(&q, &kc, &vc, &mut ao, 1, heads, kvh, hd, max_seq, kv_len - 1, 0, heads);
    });

    // --- RMSNorm -------------------------------------------------------------
    let d = 2048usize;
    let xr = rand_vec(d, 7);
    let g = rand_vec(d, 8);
    let mut outn = vec![0.0f32; d];
    bench(&format!("rmsnorm d={d}"), 50, || {
        ops::norm::rmsnorm(&xr, &g, &mut outn, d, 1e-6, 0, 1);
    });

    // --- end-to-end decode step (real engine, small model) -------------------
    println!("\n== end-to-end decode (small-25m, real engine) ==\n");
    for threads in [1usize, 2, 4] {
        let opts = EngineOptions {
            strategy: Strategy::arclight_single(),
            threads,
            topo: Topology::kunpeng920(),
            prefill_rows: None,
            seed: 0,
        };
        let mut engine = Engine::new_synthetic(ModelConfig::small_25m(), &opts).unwrap();
        engine.prefill(&[1, 2, 3, 4]);
        let mut step = 0usize;
        let t = bench(&format!("decode step, {threads} worker(s)"), 12, || {
            let logits = engine.decode_step((step % 200) as i32 + 5);
            step += 1;
            std::hint::black_box(&logits);
            if engine.position() > 400 {
                engine.reset();
                engine.prefill(&[1, 2, 3, 4]);
            }
        });
        println!("{:42} {:>8.1} tok/s", "", 1.0 / t);
    }

    // --- generation sanity ----------------------------------------------------
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        topo: Topology::kunpeng920(),
        prefill_rows: None,
        seed: 0,
    };
    let mut engine = Engine::new_synthetic(ModelConfig::small_25m(), &opts).unwrap();
    let res = engine.generate(&[1, 2, 3, 4, 5], 32, &Sampler::greedy());
    println!("\ngenerate 32 tokens: {:.1} tok/s decode", res.decode_tok_per_s());
}
