//! Real wall-clock microbenchmarks of the operator hot paths (the §Perf
//! targets): Q4_0 GEMV/GEMM, the scheduler's dispatch overhead (per-op
//! jobs vs one compiled pass), fused attention, RMSNorm, and the
//! end-to-end decode step of the real engine on the small model —
//! single-sequence and continuous-batched. The JSON report carries
//! `dispatches_per_token` for the perf trajectory.
//!
//! These are host-machine numbers (1 core in this environment), used for
//! the optimization loop — the paper-figure numbers come from the
//! simulated testbed instead.
//!
//!     cargo bench --bench ops_hotpath [-- --quick] [-- --json <path>] [-- --pin]
//!
//! `--quick` shrinks sizes/iterations for the CI bench-smoke leg;
//! `--json <path>` writes the measured per-iteration seconds as a JSON
//! report (the perf-trajectory artifact); `--pin` runs the end-to-end
//! engines on the detected host platform with pinned workers and
//! first-touch arenas (degrades to simulated when unavailable).

use std::sync::Arc;
use std::time::Instant;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::hw::{membind, Platform};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::ops;
use arclight::quant::quantize_matrix_q4_0;
use arclight::threads::{ThreadPool, WorkerCtx};
use arclight::util::json::{obj, Json};
use arclight::util::stats::{fmt_duration, Summary};
use arclight::util::Rng;

/// warmup + timed iterations; returns per-iteration seconds and logs
/// the sample into `report`.
fn bench<F: FnMut()>(report: &mut Vec<(String, f64)>, name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..3 {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    let p50 = s.p50();
    println!("{name:42} {:>12}/iter  (min {:>12})", fmt_duration(p50), fmt_duration(s.min()));
    report.push((name.to_string(), p50));
    p50
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v, 1.0);
    v
}

fn engine_opts(
    platform: &Platform,
    pin: bool,
    threads: usize,
    batch_slots: usize,
) -> EngineOptions {
    EngineOptions {
        strategy: Strategy::arclight_single(),
        threads,
        platform: platform.clone(),
        prefill_rows: None,
        seed: 0,
        batch_slots,
        pin,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let pin = args.iter().any(|a| a == "--pin");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    // worker threads the end-to-end engine sections below actually use
    let max_engine_threads = if quick { 2 } else { 4 };
    let platform = if pin {
        let (p, note) = Platform::host_with_membind(max_engine_threads);
        if let Some(why) = note {
            println!("--pin requested but {why}; running simulated");
        }
        p
    } else {
        Platform::simulated()
    };
    let mut pinned_workers = 0usize;
    let mut report: Vec<(String, f64)> = Vec::new();
    let rep = &mut report;

    println!(
        "== operator hot paths (host wall-clock{}) ==\n",
        if quick { ", quick mode" } else { "" }
    );

    // --- Q4_0 GEMV: the decode inner loop -----------------------------------
    let (n, k) = if quick { (512usize, 512usize) } else { (2048usize, 2048usize) };
    let gemv_iters = if quick { 5 } else { 20 };
    let w = rand_vec(n * k, 1);
    let wq = quantize_matrix_q4_0(&w, n, k);
    let x = rand_vec(k, 2);
    let mut out = vec![0.0f32; n];
    let t = bench(rep, &format!("q4_0 gemv {n}x{k}"), gemv_iters, || {
        ops::gemm::gemm_q4_0(&x, &wq, &mut out, 1, k, n, 0, n);
    });
    let bytes = wq.len() as f64;
    let gbs = bytes / t / 1e9;
    let gflops = 2.0 * (n * k) as f64 / t / 1e9;
    println!("{:42} {gbs:>8.2} GB/s weight stream, {gflops:>6.2} GFLOP/s", "");

    // --- f32 GEMV reference --------------------------------------------------
    let mut out_f = vec![0.0f32; n];
    let tf = bench(rep, &format!("f32 gemv {n}x{k}"), gemv_iters, || {
        ops::gemm::gemm_f32(&x, &w, &mut out_f, 1, k, n, 0, n);
    });
    println!("{:42} q4/f32 time ratio: {:.2} (q4 moves 7.1x fewer bytes)", "", t / tf);

    // --- batched GEMM (m = 8): the continuous-batching decode shape ----------
    let m = 8usize;
    let xm = rand_vec(m * k, 3);
    let mut outm = vec![0.0f32; m * n];
    let tm = bench(rep, &format!("q4_0 gemm {m}x{k} · {n}x{k}ᵀ"), gemv_iters.max(10), || {
        ops::gemm::gemm_q4_0(&xm, &wq, &mut outm, m, k, n, 0, n);
    });
    println!(
        "{:42} {:>8.2} GFLOP/s, {:.2}x the GEMV time for {m}x the tokens",
        "",
        2.0 * (m * n * k) as f64 / tm / 1e9,
        tm / t
    );

    // --- dispatch overhead: per-op jobs vs one compiled pass -----------------
    // The §3.3 scheduling tax in isolation: N empty "operators" run
    // either as N boxed-job dispatches (send + alloc + latch each, the
    // legacy walk) or as ONE run_pass dispatch whose workers walk N
    // barrier-separated phases themselves (the PassPlan model).
    {
        let workers = 4usize;
        let n_ops = if quick { 64usize } else { 256usize };
        let disp_iters = if quick { 5 } else { 20 };
        let topo = Topology::kunpeng920();
        let cores: Vec<_> = (0..workers).map(|i| topo.core(i)).collect();
        let pool = ThreadPool::new(cores);
        let name_old = format!("dispatch {n_ops} empty ops, per-op path");
        let t_old = bench(rep, &name_old, disp_iters, || {
            for _ in 0..n_ops {
                pool.run_all(Arc::new(|_: &WorkerCtx| {}));
            }
        });
        let gb = pool.global_barrier();
        let name_new = format!("dispatch {n_ops} empty ops, pass path");
        let t_new = bench(rep, &name_new, disp_iters, || {
            let gb = gb.clone();
            pool.run_pass(Arc::new(move |_: &WorkerCtx| {
                for _ in 0..n_ops {
                    gb.wait();
                }
            }));
        });
        println!(
            "{:42} {:.2}x dispatch-tax reduction ({} dispatches -> 1 per pass)",
            "",
            t_old / t_new,
            n_ops
        );
    }

    // --- fused attention over the KV cache -----------------------------------
    let (heads, kvh, hd) = (16usize, 8usize, 64usize);
    let (max_seq, kv_len) = if quick { (128usize, 96usize) } else { (512usize, 384usize) };
    let q = rand_vec(heads * hd, 4);
    let kc = rand_vec(kvh * max_seq * hd, 5);
    let vc = rand_vec(kvh * max_seq * hd, 6);
    let mut ao = vec![0.0f32; heads * hd];
    bench(rep, &format!("attention decode H={heads} kv_len={kv_len}"), gemv_iters, || {
        let p0 = kv_len - 1;
        ops::attention::attention(&q, &kc, &vc, &mut ao, 1, heads, kvh, hd, max_seq, p0, 0, heads);
    });

    // --- RMSNorm -------------------------------------------------------------
    let d = 2048usize;
    let xr = rand_vec(d, 7);
    let g = rand_vec(d, 8);
    let mut outn = vec![0.0f32; d];
    bench(rep, &format!("rmsnorm d={d}"), if quick { 10 } else { 50 }, || {
        ops::norm::rmsnorm(&xr, &g, &mut outn, d, 1e-6, 0, 1);
    });

    // --- end-to-end decode step (real engine, small model) -------------------
    println!("\n== end-to-end decode (small-25m, real engine) ==\n");
    let cfg = if quick { ModelConfig::tiny() } else { ModelConfig::small_25m() };
    let thread_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    let step_iters = if quick { 4 } else { 12 };
    // dispatch tax of a real decode pass: pool dispatches per decoded
    // token (1 under the compiled-pass scheduler)
    let mut dispatches_per_token = 0.0f64;
    for &threads in thread_counts {
        let mut engine =
            Engine::new_synthetic(cfg.clone(), &engine_opts(&platform, pin, threads, 1)).unwrap();
        pinned_workers = pinned_workers.max(engine.pinned_workers());
        engine.prefill(&[1, 2, 3, 4]);
        let horizon = cfg.max_seq - 24;
        let mut step = 0usize;
        let t = bench(rep, &format!("decode step, {threads} worker(s)"), step_iters, || {
            let logits = engine.decode_step((step % 200) as i32 + 5);
            step += 1;
            std::hint::black_box(&logits);
            if engine.position() > horizon {
                engine.reset();
                engine.prefill(&[1, 2, 3, 4]);
            }
        });
        dispatches_per_token = engine
            .last_step_report()
            .map(|r| r.dispatches as f64)
            .unwrap_or(0.0);
        println!(
            "{:42} {:>8.1} tok/s ({} dispatch/token)",
            "",
            1.0 / t,
            dispatches_per_token
        );
    }

    // --- batched decode step (continuous batching, 4 live sequences) ---------
    {
        let slots = 4usize;
        let mut engine =
            Engine::new_synthetic(cfg.clone(), &engine_opts(&platform, pin, 2, slots)).unwrap();
        let mut seqs: Vec<_> = (0..slots).map(|_| engine.seq_alloc().unwrap()).collect();
        let horizon = cfg.max_seq - 24;
        let mut step = 0usize;
        let t = bench(rep, &format!("batched decode step, {slots} lanes"), step_iters, || {
            let lanes: Vec<_> = seqs.iter().map(|&s| (s, (step % 200) as i32 + 5)).collect();
            let logits = engine.step_batch(&lanes);
            step += 1;
            std::hint::black_box(&logits);
            if seqs.iter().any(|&s| engine.seq_pos(s) > horizon) {
                engine.reset();
                seqs = (0..slots).map(|_| engine.seq_alloc().unwrap()).collect();
            }
        });
        println!("{:42} {:>8.1} tok/s aggregate", "", slots as f64 / t);
    }

    // --- generation sanity ----------------------------------------------------
    let mut engine = Engine::new_synthetic(cfg, &engine_opts(&platform, pin, 2, 1)).unwrap();
    let res = engine.generate(&[1, 2, 3, 4, 5], if quick { 8 } else { 32 }, &Sampler::greedy());
    println!("\ngenerate {} tokens: {:.1} tok/s decode", res.decode_tokens, res.decode_tok_per_s());

    if let Some(path) = json_path {
        let entries: Vec<Json> = report
            .iter()
            .map(|(name, secs)| {
                obj(vec![("name", name.as_str().into()), ("p50_s", (*secs).into())])
            })
            .collect();
        let j = obj(vec![
            ("benchmark", "ops_hotpath".into()),
            ("quick", quick.into()),
            ("platform", platform.name().into()),
            ("pinned_workers", pinned_workers.into()),
            ("node_local_bytes", (membind::node_local_bytes() as usize).into()),
            ("dispatches_per_token", dispatches_per_token.into()),
            ("results", Json::Arr(entries)),
        ]);
        if let Some(parent) = std::path::Path::new(&path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(&path, j.to_string()).expect("write json report");
        println!("wrote report to {path}");
    }
}
