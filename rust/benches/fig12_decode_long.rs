//! Bench: Figure 12 (appendix A.2) — decode throughput with a
//! 300-token prompt on 2 and 4 NUMA nodes. Decode is slightly slower
//! than with short prompts (longer KV stream per step) but the TP
//! advantage persists.
//!
//!     cargo bench --bench fig12_decode_long

use arclight::baseline::Strategy;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::figures::{decode_tok_s, fig12};
use arclight::report::render_table;
use arclight::sched::SyncMode;

fn main() {
    let topo = Topology::kunpeng920();
    let cfg = ModelConfig::qwen3_4b();
    let t0 = std::time::Instant::now();
    for nodes in [2usize, 4] {
        let series = fig12(&cfg, &topo, nodes, 4);
        print!(
            "{}",
            render_table(
                &format!("Figure 12 (N={nodes}): decode tok/s, prompt 300 (Qwen3-4B Q4_0)"),
                "threads",
                &series
            )
        );
    }

    // appendix A.2: long-prompt decode ≤ short-prompt decode
    let tp4 = Strategy::arclight_tp(4, SyncMode::SyncB);
    let short = decode_tok_s(&cfg, tp4, 192, &topo, 15, 256, 4);
    let long = decode_tok_s(&cfg, tp4, 192, &topo, 300, 256, 4);
    println!(
        "\nArcLight-TP4 decode: prompt 15 → {:.1} tok/s, prompt 300 → {:.1} tok/s",
        short.tok_per_s, long.tok_per_s
    );
    assert!(long.tok_per_s < short.tok_per_s, "longer KV stream must cost throughput");
    assert!(long.tok_per_s > short.tok_per_s * 0.7, "the cost should be mild");
    println!("sweep time: {:.1} s", t0.elapsed().as_secs_f64());
}
