//! Bench: regenerate Table 1 (core→memory bandwidth matrix, GB/s).
//!
//! Paper reference values (Kunpeng-920, 4 nodes):
//!     102  26  24  23
//!      26 103  23  22
//!      24  23 103  26
//!      23  22  26 101
//!
//!     cargo bench --bench table1_membw

use arclight::numa::topology::KUNPENG920_BW;
use arclight::numa::Topology;
use arclight::report::table1::{bandwidth_table, render};

fn main() {
    let topo = Topology::kunpeng920();
    let t0 = std::time::Instant::now();
    let table = bandwidth_table(&topo, topo.cores_per_node, 1.0);
    let elapsed = t0.elapsed();
    print!("{}", render(&table));

    // paper-vs-measured deviation
    let mut worst = 0.0f64;
    for i in 0..4 {
        for j in 0..4 {
            let dev = (table[i][j] - KUNPENG920_BW[i][j]).abs() / KUNPENG920_BW[i][j];
            worst = worst.max(dev);
        }
    }
    println!("\nmax deviation from the paper's measurements: {:.2}%", worst * 100.0);
    println!("local/remote ratio (node 0): {:.1}x (paper: ~4x)", table[0][0] / table[0][3]);
    println!("regeneration time: {:.1} ms", elapsed.as_secs_f64() * 1e3);
    assert!(worst < 0.02, "bandwidth model drifted from Table 1");
}
