//! Bench: Figure 10 — decode throughput on a single NUMA node,
//! threads 6→48, ArcLight vs llama.cpp (`-numa isolate`).
//!
//! Workload: Qwen3-4B Q4_0, prompt 15, generation 256 (paper §4).
//!
//!     cargo bench --bench fig10_single_node

use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::{figures::fig10, render_table};

fn main() {
    let topo = Topology::kunpeng920();
    let cfg = ModelConfig::qwen3_4b();
    let t0 = std::time::Instant::now();
    let series = fig10(&cfg, &topo, 4);
    print!(
        "{}",
        render_table(
            "Figure 10: decode tok/s, single NUMA node (Qwen3-4B Q4_0, prompt 15, gen 256)",
            "threads",
            &series
        )
    );
    println!("\nsweep time: {:.1} s", t0.elapsed().as_secs_f64());

    // shape assertions from the paper's discussion:
    let llama = &series[0];
    let arc = &series[1];
    // throughput improves with threads (both frameworks)
    assert!(arc.ys.last().unwrap() > &(arc.ys[0] * 2.0), "ArcLight must scale with cores");
    assert!(llama.ys[3] > llama.ys[0] * 2.0, "llama.cpp must scale with cores");
    // ArcLight slightly higher (node-local allocation vs UMA buffer)
    let best_arc = arc.ys.iter().cloned().fold(0.0, f64::max);
    let best_llama = llama.ys.iter().cloned().fold(0.0, f64::max);
    assert!(best_arc > best_llama, "ArcLight should edge out llama.cpp on one node");
    assert!(best_arc < best_llama * 1.3, "single-node gap should be modest");
    println!(
        "single-node advantage: +{:.1}% (paper: 'slightly higher')",
        (best_arc / best_llama - 1.0) * 100.0
    );
}
