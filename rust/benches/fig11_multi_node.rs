//! Bench: Figure 11 — decode throughput across 2 and 4 NUMA nodes:
//! llama.cpp (`-numa distribute`) vs ArcLight cross-NUMA TP under both
//! synchronization modes (§3.4).
//!
//!     cargo bench --bench fig11_multi_node

use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::{figures::fig11, render_table};

fn main() {
    let topo = Topology::kunpeng920();
    let cfg = ModelConfig::qwen3_4b();
    let t0 = std::time::Instant::now();
    for nodes in [2usize, 4] {
        let series = fig11(&cfg, &topo, nodes, 4);
        print!(
            "{}",
            render_table(
                &format!("Figure 11 (N={nodes}): decode tok/s (Qwen3-4B Q4_0, prompt 15, gen 256)"),
                "threads",
                &series
            )
        );
        let best = |s: &arclight::report::FigureSeries| s.ys.iter().cloned().fold(0.0, f64::max);
        let llama = best(&series[0]);
        let sync_a = best(&series[1]);
        let sync_b = best(&series[2]);
        println!(
            "  N={nodes}: TP(SyncB) vs llama.cpp: +{:.0}% | SyncB − SyncA: +{:.1} tok/s\n",
            (sync_b / llama - 1.0) * 100.0,
            sync_b - sync_a
        );
        // paper shapes: TP wins; async subgraphs add a few tok/s
        assert!(sync_b > llama * 1.15, "TP must beat llama.cpp distribute (N={nodes})");
        assert!(sync_b >= sync_a, "Sync B must not lose to Sync A");
        // llama.cpp stops scaling at full thread count (the cross-NUMA wall)
        let llama_full = *series[0].ys.last().unwrap();
        assert!(llama_full < llama * 1.05, "llama.cpp should saturate below its peak");
    }
    println!("sweep time: {:.1} s", t0.elapsed().as_secs_f64());
}
