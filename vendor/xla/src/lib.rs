//! API-surface shim of the `xla` (xla_extension) bindings.
//!
//! The real crate links libxla and only exists in the fully-vendored
//! evaluation environment. This shim carries just enough of the API
//! that `runtime::pjrt` (the `pjrt` cargo feature) **compiles** against
//! it — the CI feature-matrix leg builds both halves of the PJRT gate.
//! Every runtime entry point fails at [`PjRtClient::cpu`], so a
//! `--features pjrt` build without the real bindings reports a clear
//! load error instead of silently pretending to execute HLO.
//!
//! Swapping in the real bindings is a path change in `rust/Cargo.toml`;
//! no source edits.

use std::fmt;

/// Error type mirroring the real crate's (message-only here).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB: &str = "xla shim: the real xla_extension bindings are not vendored in this \
                    environment (see vendor/xla/src/lib.rs)";

/// Element types the runtime constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F16,
    S32,
    U8,
}

/// A host literal (opaque in the shim).
#[derive(Debug, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal(())
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::new(STUB))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::new(STUB))
    }
}

/// Parsed HLO module (opaque).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new(STUB))
    }
}

/// A computation handed to the compiler (opaque).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB))
    }
}

/// Compiled executable (opaque).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB))
    }
}

/// The PJRT CPU client. In the shim, construction itself fails — the
/// earliest, clearest place to say the bindings are absent.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(STUB))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_missing_bindings() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla shim"));
    }

    #[test]
    fn literal_constructors_are_inert() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        let raw = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8]);
        assert!(raw.is_ok());
    }
}
