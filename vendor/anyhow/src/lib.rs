//! A minimal, std-only, API-compatible shim for the subset of
//! [`anyhow`](https://docs.rs/anyhow) this workspace uses.
//!
//! The build environments for this repo are offline (everything is
//! vendored), so instead of pulling the real crate from crates.io the
//! workspace ships this ~150-line stand-in. It provides:
//!
//! * [`Error`] — a single-message error value (no backtraces, no chain
//!   downcasting; context is folded into the message eagerly);
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default
//!   error type;
//! * [`Context`] — `.context(...)` / `.with_context(...)` on both
//!   `Result` and `Option`;
//! * [`anyhow!`] / [`bail!`] — the formatting constructors.
//!
//! `?` works on any `std::error::Error` (io, utf8, slice conversions,
//! …) through a blanket `From` impl, exactly like the real crate. If
//! the workspace ever gains network access, swapping this for the real
//! `anyhow = "1"` is a one-line change in `rust/Cargo.toml`.

use std::fmt;

/// A string-backed error value. Display prints the message with every
/// context layer prepended (`outer: inner`), matching the `{:#}`
/// rendering of real anyhow closely enough for logs and tests.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` reports through Debug;
        // print the plain message like real anyhow does.
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as
// the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — result with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap with a lazily-computed context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: context.to_string() })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag} ({})", 42);
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        let e = inner(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true (42)");

        let from_string = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");
        let n = 3;
        let formatted = anyhow!("n = {n}");
        assert_eq!(formatted.to_string(), "n = 3");
    }
}
