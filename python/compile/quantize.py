"""Q4_0 block quantization (llama.cpp/ggml-compatible layout).

A Q4_0 block covers 32 consecutive elements along the contraction (K)
axis and is stored as:

    d  : float16 scale (2 bytes)
    qs : 16 bytes; element ``i`` (0 <= i < 16) lives in the low nibble of
         byte ``i`` and element ``i + 16`` in the high nibble of byte ``i``.

Dequantization: ``x[i] = (q[i] - 8) * float32(d)``.

The quantization rule mirrors ggml's ``quantize_row_q4_0`` exactly: the
scale is derived from the signed value with the largest magnitude so that
it maps to the nibble 0 (i.e. -8 after bias removal), which keeps the
codebook symmetric around the data's dominant sign.

The Rust side (``rust/src/quant``) implements the same layout; the two are
cross-checked through the ALF weight files and the PJRT golden tests.
"""

from __future__ import annotations

import numpy as np

QK4_0 = 32  # elements per block
BLOCK_BYTES = 18  # 2 (f16 scale) + 16 (nibbles)


def quantize_q4_0(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Quantize ``x`` ([..., K], K % 32 == 0, float32) to Q4_0.

    Returns ``(qs, d)`` where ``qs`` is uint8 [..., K/32, 16] (packed
    nibbles) and ``d`` is float16 [..., K/32] (per-block scales).
    """
    x = np.asarray(x, dtype=np.float32)
    k = x.shape[-1]
    if k % QK4_0 != 0:
        raise ValueError(f"K={k} is not a multiple of {QK4_0}")
    blocks = x.reshape(*x.shape[:-1], k // QK4_0, QK4_0)

    # ggml: pick the signed value with max |x|, scale = max / -8.
    amax_idx = np.abs(blocks).argmax(axis=-1, keepdims=True)
    maxv = np.take_along_axis(blocks, amax_idx, axis=-1)[..., 0]
    d = (maxv / -8.0).astype(np.float16)
    d32 = d.astype(np.float32)
    with np.errstate(divide="ignore", invalid="ignore"):
        inv_d = np.where(d32 != 0.0, 1.0 / d32, 0.0)

    q = blocks * inv_d[..., None] + 8.5
    q = np.clip(q, 0.0, 15.0).astype(np.uint8)

    lo = q[..., :16]
    hi = q[..., 16:]
    qs = (lo | (hi << 4)).astype(np.uint8)
    return qs, d


def dequantize_q4_0(qs: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_q4_0` → float32 [..., K]."""
    lo = (qs & 0x0F).astype(np.int32) - 8
    hi = (qs >> 4).astype(np.int32) - 8
    blocks = np.concatenate([lo, hi], axis=-1).astype(np.float32)
    blocks = blocks * d.astype(np.float32)[..., None]
    return blocks.reshape(*qs.shape[:-2], qs.shape[-2] * QK4_0)


def pack_q4_0_bytes(qs: np.ndarray, d: np.ndarray) -> bytes:
    """Serialize a 2-D quantized weight ([N, K/32, 16] + [N, K/32]) into
    the ALF/ggml on-disk stream: per block, f16 scale then 16 nibble bytes,
    row-major over (N, K/32)."""
    n, nb, _ = qs.shape
    out = np.zeros((n, nb, BLOCK_BYTES), dtype=np.uint8)
    out[..., :2] = d.astype("<f2").view(np.uint8).reshape(n, nb, 2)
    out[..., 2:] = qs
    return out.tobytes()


def unpack_q4_0_bytes(raw: bytes, n: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_q4_0_bytes`."""
    nb = k // QK4_0
    arr = np.frombuffer(raw, dtype=np.uint8).reshape(n, nb, BLOCK_BYTES)
    d = arr[..., :2].copy().view("<f2").reshape(n, nb)
    qs = arr[..., 2:].copy()
    return qs, d
