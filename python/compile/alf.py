"""ALF (ArcLight Format) weight files — the repo's GGUF stand-in.

Layout (little-endian):

    magic   : 4 bytes  b"ALF1"
    version : u32      (currently 1)
    meta_len: u64      length of the JSON metadata blob
    meta    : meta_len bytes of UTF-8 JSON:
                {"config": {...model geometry...},
                 "tensors": [{"name", "dtype", "shape", "offset", "nbytes"}]}
    pad     : zero padding so the data region starts 64-byte aligned
    data    : tensor payloads, each 64-byte aligned, offsets relative to
              the start of the data region

Dtypes: "f32" (raw little-endian floats) and "q4_0" (ggml block stream:
per 32 elements, f16 scale + 16 nibble bytes — see quantize.py). For
q4_0 the logical shape is [N, K]; nbytes = N * K/32 * 18.

The Rust loader lives in ``rust/src/model/alf.rs`` and must accept
exactly what this writer emits (covered by the golden integration test).
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"ALF1"
VERSION = 1
ALIGN = 64


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def write_alf(path: str, config: dict, tensors: list[tuple[str, str, tuple, bytes]]) -> None:
    """Write an ALF file. ``tensors`` = [(name, dtype, shape, payload)]."""
    table = []
    offset = 0
    for name, dtype, shape, payload in tensors:
        offset = _align(offset)
        table.append({"name": name, "dtype": dtype, "shape": list(shape),
                      "offset": offset, "nbytes": len(payload)})
        offset += len(payload)

    meta = json.dumps({"config": config, "tensors": table}).encode()
    header_len = len(MAGIC) + 4 + 8 + len(meta)
    data_start = _align(header_len)

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", VERSION))
        f.write(struct.pack("<Q", len(meta)))
        f.write(meta)
        f.write(b"\x00" * (data_start - header_len))
        pos = 0
        for (name, dtype, shape, payload), entry in zip(tensors, table):
            pad = entry["offset"] - pos
            f.write(b"\x00" * pad)
            f.write(payload)
            pos = entry["offset"] + len(payload)


def read_alf(path: str) -> tuple[dict, dict[str, dict]]:
    """Read an ALF file → (config, {name: {dtype, shape, data(bytes)}}).

    Mirror of the Rust loader; used by tests to round-trip."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] != MAGIC:
        raise ValueError("not an ALF file")
    version = struct.unpack_from("<I", raw, 4)[0]
    if version != VERSION:
        raise ValueError(f"unsupported ALF version {version}")
    meta_len = struct.unpack_from("<Q", raw, 8)[0]
    meta = json.loads(raw[16:16 + meta_len].decode())
    data_start = _align(16 + meta_len)
    out = {}
    for t in meta["tensors"]:
        lo = data_start + t["offset"]
        out[t["name"]] = {
            "dtype": t["dtype"],
            "shape": tuple(t["shape"]),
            "data": raw[lo:lo + t["nbytes"]],
        }
    return meta["config"], out


def f32_payload(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr, dtype="<f4").tobytes()
