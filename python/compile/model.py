"""Layer 2 — Qwen3-architecture decoder in JAX, calling the Pallas kernels.

This is the paper's "frontend model definition" expressed as a pure JAX
function over an explicit parameter pytree, so it can be AOT-lowered once
(``aot.py``) and executed from the Rust runtime via PJRT. The same
architecture is independently implemented by the Rust engine
(``rust/src/model``); the two are cross-checked by the golden integration
tests through identical ALF weight bytes.

Architecture (Qwen3, the paper's eval model):
  token emb → L × [RMSNorm → GQA attn (per-head QK-norm, RoPE) → residual
              → RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.
All seven projection matrices per layer plus the LM head are Q4_0
quantized (paper §4: Qwen3-4B in Q4_0) and contracted by the Pallas
``q4_gemm`` kernel; attention runs through the Pallas tiled-attention
kernel; the layer norms through the Pallas ``rmsnorm`` kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import attention
from .kernels.q4gemm import q4_gemm
from .kernels.rmsnorm import rmsnorm
from .quantize import quantize_q4_0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of a Qwen3-family decoder.

    ``dim``, ``n_heads*head_dim`` and ``ffn_dim`` must be multiples of 32
    (the Q4_0 block size along contraction axes).
    """

    dim: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 16
    ffn_dim: int = 128
    vocab: int = 512
    max_seq: int = 64
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def validate(self) -> None:
        for name, val in (("dim", self.dim), ("q_dim", self.q_dim),
                          ("ffn_dim", self.ffn_dim)):
            if val % 32:
                raise ValueError(f"{name}={val} not a multiple of 32 (Q4_0)")
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


# Tiny geometry used for the AOT artifacts + golden tests. Small enough
# that PJRT round-trips are fast, large enough that every code path
# (GQA replication, multi-layer KV, Q4_0 blocks) is exercised.
TINY = ModelConfig()


def _qw(rng: np.random.Generator, n: int, k: int, scale: float):
    """Generate and Q4_0-quantize an [n, k] projection."""
    w = (rng.standard_normal((n, k)) * scale).astype(np.float32)
    qs, d = quantize_q4_0(w)
    return {"qs": jnp.asarray(qs), "d": jnp.asarray(d.astype(np.float32))}


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Deterministic synthetic weights (the paper's throughput results do
    not depend on weight values; numerics tests only need stability)."""
    cfg.validate()
    rng = np.random.default_rng(seed)
    s_in = 1.0 / np.sqrt(cfg.dim)
    s_ffn = 1.0 / np.sqrt(cfg.ffn_dim)
    s_qd = 1.0 / np.sqrt(cfg.q_dim)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "attn_norm": jnp.asarray(1.0 + 0.1 * rng.standard_normal(cfg.dim).astype(np.float32)),
            "wq": _qw(rng, cfg.q_dim, cfg.dim, s_in),
            "wk": _qw(rng, cfg.kv_dim, cfg.dim, s_in),
            "wv": _qw(rng, cfg.kv_dim, cfg.dim, s_in),
            "wo": _qw(rng, cfg.dim, cfg.q_dim, s_qd),
            "q_norm": jnp.asarray(1.0 + 0.1 * rng.standard_normal(cfg.head_dim).astype(np.float32)),
            "k_norm": jnp.asarray(1.0 + 0.1 * rng.standard_normal(cfg.head_dim).astype(np.float32)),
            "mlp_norm": jnp.asarray(1.0 + 0.1 * rng.standard_normal(cfg.dim).astype(np.float32)),
            "w_gate": _qw(rng, cfg.ffn_dim, cfg.dim, s_in),
            "w_up": _qw(rng, cfg.ffn_dim, cfg.dim, s_in),
            "w_down": _qw(rng, cfg.dim, cfg.ffn_dim, s_ffn),
        })
    return {
        "tok_emb": jnp.asarray((rng.standard_normal((cfg.vocab, cfg.dim)) * 0.02).astype(np.float32)),
        "layers": layers,
        "final_norm": jnp.asarray(1.0 + 0.1 * rng.standard_normal(cfg.dim).astype(np.float32)),
        "lm_head": _qw(rng, cfg.vocab, cfg.dim, s_in),
    }


def _per_head_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Qwen3 QK-norm: RMSNorm over head_dim for each head. x: [..., H, D]."""
    return ref.rmsnorm(x, g, eps)


def _attn_block(layer: dict, cfg: ModelConfig, h: jnp.ndarray,
                pos0, k_cache: jnp.ndarray, v_cache: jnp.ndarray):
    """Shared attention block. h: [T, dim]; caches: [KV, max_seq, hd].

    ``pos0`` is the absolute position of h[0] (0 for prefill, the current
    step for decode). Returns (out [T, dim], k_cache, v_cache).
    """
    t = h.shape[0]
    rep = cfg.n_heads // cfg.n_kv_heads
    positions = jnp.arange(t, dtype=jnp.int32) + pos0

    q = q4_gemm(h, layer["wq"]["qs"], layer["wq"]["d"]).reshape(t, cfg.n_heads, cfg.head_dim)
    k = q4_gemm(h, layer["wk"]["qs"], layer["wk"]["d"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)
    v = q4_gemm(h, layer["wv"]["qs"], layer["wv"]["d"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)

    q = _per_head_norm(q, layer["q_norm"], cfg.norm_eps)
    k = _per_head_norm(k, layer["k_norm"], cfg.norm_eps)

    # RoPE over the sequence axis (ref.rope expects [..., T, D]).
    q = ref.rope(q.transpose(1, 0, 2), positions, cfg.rope_theta)  # [H, T, hd]
    k = ref.rope(k.transpose(1, 0, 2), positions, cfg.rope_theta)  # [KV, T, hd]
    v = v.transpose(1, 0, 2)                                       # [KV, T, hd]

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos0, 0))

    kq = jnp.repeat(k_cache, rep, axis=0)  # GQA broadcast → [H, max_seq, hd]
    vq = jnp.repeat(v_cache, rep, axis=0)
    o = attention(q, kq, vq, causal=True, q_offset=pos0,
                  block_k=min(128, cfg.max_seq))           # [H, T, hd]
    o = o.transpose(1, 0, 2).reshape(t, cfg.q_dim)
    out = q4_gemm(o, layer["wo"]["qs"], layer["wo"]["d"])
    return out, k_cache, v_cache


def _mlp_block(layer: dict, h: jnp.ndarray) -> jnp.ndarray:
    gate = q4_gemm(h, layer["w_gate"]["qs"], layer["w_gate"]["d"])
    up = q4_gemm(h, layer["w_up"]["qs"], layer["w_up"]["d"])
    return q4_gemm(ref.silu(gate) * up, layer["w_down"]["qs"], layer["w_down"]["d"])


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, pos0,
            k_caches: jnp.ndarray, v_caches: jnp.ndarray):
    """Forward ``tokens`` ([T] int32) starting at absolute position ``pos0``.

    k_caches/v_caches: [L, KV, max_seq, hd]. Returns
    (logits [T, vocab], k_caches, v_caches).
    """
    x = params["tok_emb"][tokens]  # [T, dim]
    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        attn_out, kc, vc = _attn_block(layer, cfg, h, pos0,
                                       k_caches[li], v_caches[li])
        x = x + attn_out
        h = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _mlp_block(layer, h)
        new_k.append(kc)
        new_v.append(vc)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = q4_gemm(x, params["lm_head"]["qs"], params["lm_head"]["d"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def make_decode_fn(cfg: ModelConfig):
    """decode(params, token [i32 scalar], pos [i32 scalar], k, v) →
    (logits [vocab], k, v) — one autoregressive step."""

    def decode(params, token, pos, k_caches, v_caches):
        logits, kc, vc = forward(params, cfg, token.reshape(1), pos,
                                 k_caches, v_caches)
        return logits[0], kc, vc

    return decode


def make_prefill_fn(cfg: ModelConfig, prompt_len: int):
    """prefill(params, tokens [prompt_len]) → (logits_last [vocab], k, v).

    Caches start from zero; prompt length is static at AOT time."""

    def prefill(params, tokens):
        k0 = jnp.zeros((cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
        v0 = jnp.zeros_like(k0)
        logits, kc, vc = forward(params, cfg, tokens, 0, k0, v0)
        return logits[prompt_len - 1], kc, vc

    return prefill
