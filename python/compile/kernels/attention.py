"""Pallas tiled-attention kernel (Layer 1).

A flash-attention-style kernel with online softmax: the KV sequence is
processed in tiles, keeping a running (max, sum, weighted-V) triple per
query row so the full score matrix never materializes. On the paper's CPU
this is the "FlashAttention" operator of §2.7; on TPU the KV tiles stream
HBM→VMEM via BlockSpec while the running stats live in VMEM scratch.

Grid: (heads, Tk/block_k). The per-head query block (decode: one row,
prefill: the whole query) stays resident; each grid step folds one KV tile
into the running softmax.

The query offset (absolute position of query row 0 in the KV sequence) is
a *dynamic* scalar operand so a single lowered module serves every decode
position — it rides in as a (1,)-shaped int32 array. ``interpret=True``
as everywhere in this repo (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, block_k: int):
    """Fold one KV tile into the online-softmax state of one head.

    off_ref: [1]          int32 — absolute position of query row 0
    q_ref  : [Tq, D]      query rows for this head
    k_ref  : [block_k, D] KV tile
    v_ref  : [block_k, D]
    o_ref  : [Tq, D]      output (written on the last KV step)
    m_ref  : [Tq]    scratch — running row max
    l_ref  : [Tq]    scratch — running row sum
    acc_ref: [Tq, D] scratch — running weighted V
    """
    kk = pl.program_id(1)
    tq = q_ref.shape[0]

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full((tq,), NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros((tq,), jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [Tq, bk]

    if causal:
        qpos = jnp.arange(tq, dtype=jnp.int32)[:, None] + off_ref[0]
        kpos = jnp.arange(block_k, dtype=jnp.int32)[None, :] + kk * block_k
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1.
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    correction = jnp.exp(m_prev - m_cur)
    correction = jnp.where(m_prev <= NEG_INF / 2, 0.0, correction)

    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kk == pl.num_programs(1) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_k"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, q_offset=0,
              block_k: int = 128) -> jnp.ndarray:
    """Tiled attention. q: [H, Tq, D]; k, v: [H, Tk, D] → [H, Tq, D] f32.

    KV heads must already be broadcast to H (GQA replication happens in
    the model layer, which on TPU is a zero-copy reshape-view).
    ``q_offset`` (python int or traced int32 scalar) anchors causal
    masking for decode (Tq=1 at position Tk-1) and chunked prefill.
    """
    h, tq, dim = q.shape
    tk = k.shape[1]
    bk = min(block_k, tk)
    if tk % bk:
        bk = tk
    scale = 1.0 / (dim ** 0.5)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=(h, tk // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda hh, kk: (0,)),
            pl.BlockSpec((None, tq, dim), lambda hh, kk: (hh, 0, 0)),
            pl.BlockSpec((None, bk, dim), lambda hh, kk: (hh, kk, 0)),
            pl.BlockSpec((None, bk, dim), lambda hh, kk: (hh, kk, 0)),
        ],
        out_specs=pl.BlockSpec((None, tq, dim), lambda hh, kk: (hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dim), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, dim), jnp.float32),
        ],
        interpret=True,
    )(off, q, k, v)
