"""Pallas Q4_0 GEMM kernel — the paper's compute hot-spot (Layer 1).

ArcLight's decode path is dominated by quantized GEMV/GEMM: every matmul
reads a Q4_0 weight stream (18 bytes per 32 elements) exactly once and is
bandwidth-bound. On the paper's CPU the insight is "keep the weight stream
node-local and fuse dequantization into the inner loop". On TPU (Pallas)
the same insight becomes:

  * the packed nibbles + scales are streamed HBM→VMEM once per (n, k)
    tile via ``BlockSpec`` (VMEM plays the role of the node-local buffer),
  * dequantization happens in-register immediately before the MXU
    contraction (never materializing the f32 weight in HBM),
  * the K loop is a grid dimension accumulating into the output tile in
    f32.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel runs through the Pallas interpreter and lowers
to plain HLO — numerically identical, structurally the TPU schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QK4_0 = 32


def _q4_gemm_kernel(x_ref, qs_ref, d_ref, o_ref, *, block_k: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/block_k).

    x_ref  : [bm, block_k]              f32   activation tile
    qs_ref : [bn, block_k//32, 16]      uint8 packed nibbles
    d_ref  : [bn, block_k//32]          f32   per-block scales
    o_ref  : [bm, bn]                   f32   accumulator tile
    """
    kk = pl.program_id(2)

    # In-register dequantization: low nibbles are elements 0..16 of each
    # block, high nibbles 16..32 (ggml Q4_0 layout).
    qs = qs_ref[...]
    lo = (qs & 0x0F).astype(jnp.int32) - 8
    hi = (qs >> 4).astype(jnp.int32) - 8
    blocks = jnp.concatenate([lo, hi], axis=-1).astype(jnp.float32)
    blocks = blocks * d_ref[...][..., None]
    w = blocks.reshape(qs.shape[0], qs.shape[1] * QK4_0)  # [bn, block_k]

    # MXU contraction in f32 (on TPU this would be bf16 in / f32 acc).
    acc = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)

    # K-grid accumulation: zero-init on the first K step.
    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def q4_gemm(x: jnp.ndarray, qs: jnp.ndarray, d: jnp.ndarray,
            block_m: int = 8, block_n: int = 64, block_k: int = 256) -> jnp.ndarray:
    """y = x @ dequant_q4_0(qs, d).T via the Pallas kernel.

    x  : [M, K] float32
    qs : [N, K//32, 16] uint8
    d  : [N, K//32] float32 (scales, already widened from f16)
    →  : [M, N] float32

    Tile sizes are clamped to the problem so small test shapes work; the
    defaults are the TPU-oriented schedule (see DESIGN.md
    §Hardware-Adaptation).
    """
    m, k = x.shape
    n = qs.shape[0]
    if qs.shape[1] * QK4_0 != k:
        raise ValueError(f"K mismatch: x has {k}, qs has {qs.shape[1] * QK4_0}")

    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    # Tiles must divide evenly (static grid); fall back to full extent.
    if m % bm:
        bm = m
    if n % bn:
        bn = n
    if k % bk or bk % QK4_0:
        bk = k

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_q4_gemm_kernel, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // QK4_0, 16), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bk // QK4_0), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, qs, d)
