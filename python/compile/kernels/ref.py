"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only. pytest (``python/tests``) sweeps
shapes/dtypes with hypothesis and asserts ``allclose`` between kernel and
oracle — this file is the correctness ground truth for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp

QK4_0 = 32


def dequant_q4_0(qs: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Dequantize ggml Q4_0 ([N, K/32, 16] uint8 + [N, K/32] scale) → f32 [N, K]."""
    lo = (qs & 0x0F).astype(jnp.int32) - 8
    hi = (qs >> 4).astype(jnp.int32) - 8
    blocks = jnp.concatenate([lo, hi], axis=-1).astype(jnp.float32)
    blocks = blocks * d.astype(jnp.float32)[..., None]
    return blocks.reshape(qs.shape[0], qs.shape[1] * QK4_0)


def q4_gemm(x: jnp.ndarray, qs: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """y = x @ dequant(W).T  — x: [M, K] f32, W: Q4_0 [N, K] → y: [M, N] f32."""
    w = dequant_q4_0(qs, d)
    return x @ w.T


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMS normalization over the last axis with learned gain ``g``."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * (1.0 / jnp.sqrt(ms + eps)) * g).astype(x.dtype)


def softmax(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 1_000_000.0) -> jnp.ndarray:
    """Rotary position embedding, NeoX/Qwen half-split style.

    x: [..., T, D] with even D; pos: [T] int32 positions.
    Pairs are (x[..., :D/2], x[..., D/2:]) — matching Qwen3/HF rotate_half.
    """
    half = x.shape[-1] // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, q_offset: int = 0) -> jnp.ndarray:
    """Multi-head scaled-dot-product attention reference.

    q: [H, Tq, D]; k, v: [H, Tk, D] (KV heads already broadcast to H).
    ``q_offset`` is the absolute position of q[.., 0, ..] within the kv
    sequence (decode: Tq == 1, q_offset == Tk - 1).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        tq, tk = q.shape[1], k.shape[1]
        qpos = jnp.arange(tq)[:, None] + q_offset
        kpos = jnp.arange(tk)[None, :]
        mask = kpos <= qpos
        scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = softmax(scores)
    return jnp.einsum("hqk,hkd->hqd", probs, v)
