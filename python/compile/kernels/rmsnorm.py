"""Pallas RMSNorm kernel (Layer 1).

Row-wise RMS normalization with a learned gain. Trivially memory-bound;
included so the whole per-layer normalize→project→attend chain lowers
through Pallas and the VMEM residency story in DESIGN.md
§Hardware-Adaptation covers the full decode hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm over the last axis. x: [T, D] (or [D]), g: [D]."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    t, d = x.shape
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((None, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, g)
    return out[0] if squeeze else out
