"""AOT entry point: lower the L2 model to HLO-text artifacts for Rust.

Run once by ``make artifacts`` (never on the request path):

  artifacts/decode.hlo.txt   — one autoregressive step (dynamic position)
  artifacts/prefill.hlo.txt  — prompt ingestion at a fixed prompt length
  artifacts/tiny.alf         — the tiny model's weights (ALF format)
  artifacts/manifest.json    — geometry + the exact flattened argument
                               order of both HLO entry points, so the Rust
                               runtime can feed PJRT literals positionally

Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import alf
from . import model as M
from .quantize import pack_q4_0_bytes

PROMPT_LEN = 16
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(x) -> str:
    return {"float32": "f32", "uint8": "u8", "int32": "i32"}[str(x.dtype)]


def flat_args(tree) -> list[dict]:
    """Flatten a pytree the same way jax.jit will, recording name/shape/dtype."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "".join(
            f".{p.key}" if hasattr(p, "key") else f".{p.idx}" for p in path
        ).lstrip(".")
        out.append({"name": name, "dtype": _dtype_name(leaf),
                    "shape": list(np.shape(leaf))})
    return out


def params_to_alf_tensors(params: dict, cfg: M.ModelConfig) -> list:
    """Serialize the parameter pytree into ALF tensor records.

    Q4_0 weights ({"qs", "d"} dicts) are re-packed into the ggml block
    stream; everything else is raw f32.
    """
    tensors = []

    def emit(name: str, node):
        if isinstance(node, dict) and set(node) == {"qs", "d"}:
            qs = np.asarray(node["qs"])
            d16 = np.asarray(node["d"]).astype(np.float16)
            n, nb, _ = qs.shape
            tensors.append((name, "q4_0", (n, nb * 32),
                            pack_q4_0_bytes(qs, d16)))
        elif isinstance(node, dict):
            for k in sorted(node):
                emit(f"{name}.{k}" if name else k, node[k])
        elif isinstance(node, list):
            for i, v in enumerate(node):
                emit(f"{name}.{i}", v)
        else:
            arr = np.asarray(node)
            tensors.append((name, "f32", arr.shape, alf.f32_payload(arr)))

    emit("", params)
    return tensors


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--prompt-len", type=int, default=PROMPT_LEN)
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)

    cfg = M.TINY
    params = M.init_params(cfg, seed=args.seed)

    # --- weights -----------------------------------------------------------
    alf.write_alf(os.path.join(out, "tiny.alf"), cfg.to_dict(),
                  params_to_alf_tensors(params, cfg))

    # --- decode step -------------------------------------------------------
    decode = M.make_decode_fn(cfg)
    kv_spec = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered_dec = jax.jit(decode).lower(params, tok_spec, tok_spec, kv_spec, kv_spec)
    with open(os.path.join(out, "decode.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_dec))

    # --- prefill -----------------------------------------------------------
    prefill = M.make_prefill_fn(cfg, args.prompt_len)
    toks_spec = jax.ShapeDtypeStruct((args.prompt_len,), jnp.int32)
    lowered_pre = jax.jit(prefill).lower(params, toks_spec)
    with open(os.path.join(out, "prefill.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_pre))

    # --- manifest ----------------------------------------------------------
    manifest = {
        "config": cfg.to_dict(),
        "seed": args.seed,
        "prompt_len": args.prompt_len,
        "weights_file": "tiny.alf",
        "decode": {
            "args": (flat_args(params)
                     + [{"name": "token", "dtype": "i32", "shape": []},
                        {"name": "pos", "dtype": "i32", "shape": []},
                        {"name": "k_caches", "dtype": "f32", "shape": list(kv_spec.shape)},
                        {"name": "v_caches", "dtype": "f32", "shape": list(kv_spec.shape)}]),
            "outputs": [
                {"name": "logits", "dtype": "f32", "shape": [cfg.vocab]},
                {"name": "k_caches", "dtype": "f32", "shape": list(kv_spec.shape)},
                {"name": "v_caches", "dtype": "f32", "shape": list(kv_spec.shape)},
            ],
        },
        "prefill": {
            "args": (flat_args(params)
                     + [{"name": "tokens", "dtype": "i32", "shape": [args.prompt_len]}]),
            "outputs": [
                {"name": "logits", "dtype": "f32", "shape": [cfg.vocab]},
                {"name": "k_caches", "dtype": "f32", "shape": list(kv_spec.shape)},
                {"name": "v_caches", "dtype": "f32", "shape": list(kv_spec.shape)},
            ],
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    print(f"artifacts written to {out}")


if __name__ == "__main__":
    main()
