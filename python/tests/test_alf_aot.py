"""ALF container + AOT manifest tests."""

import json
import os

import numpy as np
import pytest

from compile import alf
from compile import model as M
from compile.aot import flat_args, params_to_alf_tensors
from compile.quantize import dequantize_q4_0, unpack_q4_0_bytes

ARTIFACTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


class TestAlfContainer:
    def test_roundtrip(self, tmp_path):
        path = str(tmp_path / "t.alf")
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        alf.write_alf(path, {"x": 1}, [("a", "f32", a.shape, alf.f32_payload(a)),
                                        ("b", "f32", (2,), alf.f32_payload(np.ones(2, np.float32)))])
        cfg, tensors = alf.read_alf(path)
        assert cfg == {"x": 1}
        got = np.frombuffer(tensors["a"]["data"], "<f4").reshape(3, 4)
        assert np.array_equal(got, a)
        assert tensors["b"]["shape"] == (2,)

    def test_alignment(self, tmp_path):
        """Every tensor payload starts 64-byte aligned in the data region."""
        path = str(tmp_path / "t.alf")
        ts = [(f"t{i}", "f32", (3,), alf.f32_payload(np.full(3, i, np.float32)))
              for i in range(5)]
        alf.write_alf(path, {}, ts)
        _, tensors = alf.read_alf(path)
        # offsets are internal, but re-reading each payload must be intact
        for i in range(5):
            got = np.frombuffer(tensors[f"t{i}"]["data"], "<f4")
            assert np.all(got == i)

    def test_bad_magic(self, tmp_path):
        p = tmp_path / "bad.alf"
        p.write_bytes(b"NOPE" + b"\x00" * 32)
        with pytest.raises(ValueError):
            alf.read_alf(str(p))


class TestParamsSerialization:
    def test_q4_weights_roundtrip_through_alf(self, tmp_path):
        cfg = M.TINY
        params = M.init_params(cfg, seed=0)
        tensors = params_to_alf_tensors(params, cfg)
        names = [t[0] for t in tensors]
        assert "tok_emb" in names and "layers.0.wq" in names and "lm_head" in names

        path = str(tmp_path / "w.alf")
        alf.write_alf(path, cfg.to_dict(), tensors)
        _, loaded = alf.read_alf(path)

        t = loaded["layers.0.wq"]
        n, k = t["shape"]
        qs, d = unpack_q4_0_bytes(t["data"], n, k)
        assert np.array_equal(qs, np.asarray(params["layers"][0]["wq"]["qs"]))
        w_alf = dequantize_q4_0(qs, d)
        w_mem = dequantize_q4_0(np.asarray(params["layers"][0]["wq"]["qs"]),
                                np.asarray(params["layers"][0]["wq"]["d"]).astype(np.float16))
        assert np.allclose(w_alf, w_mem)

    def test_flat_args_order_is_sorted_dict_order(self):
        """jax flattens dicts in sorted-key order; the manifest must agree
        with what jax.jit's HLO entry expects."""
        tree = {"b": np.zeros(1, np.float32), "a": {"y": np.zeros(2, np.float32)},
                "c": [np.zeros(3, np.float32)]}
        names = [a["name"] for a in flat_args(tree)]
        assert names == ["a.y", "b", "c.0"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    def test_manifest_matches_alf(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        cfg, tensors = alf.read_alf(os.path.join(ARTIFACTS, man["weights_file"]))
        assert cfg["dim"] == man["config"]["dim"]
        # every q4/f32 arg in the decode signature maps onto an ALF tensor
        for arg in man["decode"]["args"]:
            base = arg["name"].rsplit(".", 1)
            if arg["name"] in ("token", "pos", "k_caches", "v_caches"):
                continue
            tensor_name = base[0] if base[-1] in ("qs", "d") else arg["name"]
            assert tensor_name in tensors, tensor_name

    def test_hlo_text_artifacts_exist_and_parse(self):
        for f in ("decode.hlo.txt", "prefill.hlo.txt"):
            path = os.path.join(ARTIFACTS, f)
            assert os.path.getsize(path) > 1000
            head = open(path).read(200)
            assert "HloModule" in head
