"""Layer-2 model tests: shapes, KV-cache consistency, determinism."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.TINY
    params = M.init_params(cfg, seed=0)
    return cfg, params


class TestConfig:
    def test_tiny_is_valid(self):
        M.TINY.validate()

    def test_rejects_bad_block_multiple(self):
        with pytest.raises(ValueError):
            M.ModelConfig(dim=48).validate()

    def test_rejects_bad_gqa(self):
        with pytest.raises(ValueError):
            M.ModelConfig(n_heads=4, n_kv_heads=3).validate()

    def test_derived_dims(self):
        cfg = M.ModelConfig(n_heads=8, n_kv_heads=2, head_dim=16)
        assert cfg.q_dim == 128 and cfg.kv_dim == 32


class TestForward:
    def test_prefill_shapes(self, setup):
        cfg, params = setup
        pre = M.make_prefill_fn(cfg, prompt_len=8)
        logits, kc, vc = pre(params, jnp.arange(8, dtype=jnp.int32))
        assert logits.shape == (cfg.vocab,)
        assert kc.shape == (cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
        assert vc.shape == kc.shape

    def test_decode_shapes(self, setup):
        cfg, params = setup
        dec = M.make_decode_fn(cfg)
        kv = jnp.zeros((cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim))
        logits, kc, vc = dec(params, jnp.int32(5), jnp.int32(0), kv, kv)
        assert logits.shape == (cfg.vocab,)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_decode_matches_prefill(self, setup):
        """prefill(t..8) + decode(tok9) == prefill(t..9): the KV cache is
        exact, not approximate."""
        cfg, params = setup
        toks = jnp.asarray(np.arange(8) + 3, jnp.int32)
        pre8 = M.make_prefill_fn(cfg, prompt_len=8)
        _, kc, vc = pre8(params, toks)
        dec = M.make_decode_fn(cfg)
        l_dec, _, _ = dec(params, jnp.int32(42), jnp.int32(8), kc, vc)

        pre9 = M.make_prefill_fn(cfg, prompt_len=9)
        l_ref, _, _ = pre9(params, jnp.concatenate([toks, jnp.asarray([42], jnp.int32)]))
        assert_allclose(np.asarray(l_dec), np.asarray(l_ref), rtol=1e-4, atol=1e-4)

    def test_multi_step_decode_consistency(self, setup):
        cfg, params = setup
        toks = jnp.asarray([1, 2, 3, 4], jnp.int32)
        pre = M.make_prefill_fn(cfg, prompt_len=4)
        _, kc, vc = pre(params, toks)
        dec = M.make_decode_fn(cfg)
        seq = [9, 11, 13]
        for i, t in enumerate(seq):
            _, kc, vc = dec(params, jnp.int32(t), jnp.int32(4 + i), kc, vc)
        # final step vs full prefill
        l_dec, _, _ = dec(params, jnp.int32(17), jnp.int32(7), kc, vc)
        full = M.make_prefill_fn(cfg, prompt_len=8)
        l_ref, _, _ = full(params, jnp.asarray([1, 2, 3, 4, 9, 11, 13, 17], jnp.int32))
        assert_allclose(np.asarray(l_dec), np.asarray(l_ref), rtol=1e-4, atol=1e-4)

    def test_causality(self, setup):
        """Changing a future token cannot change an earlier position's KV."""
        cfg, params = setup
        pre = M.make_prefill_fn(cfg, prompt_len=6)
        a = jnp.asarray([1, 2, 3, 4, 5, 6], jnp.int32)
        b = jnp.asarray([1, 2, 3, 4, 5, 99], jnp.int32)
        _, ka, _ = pre(params, a)
        _, kb, _ = pre(params, b)
        assert_allclose(np.asarray(ka[:, :, :5]), np.asarray(kb[:, :, :5]),
                        rtol=1e-6, atol=1e-6)

    def test_deterministic_params(self):
        p1 = M.init_params(M.TINY, seed=7)
        p2 = M.init_params(M.TINY, seed=7)
        assert np.array_equal(np.asarray(p1["tok_emb"]), np.asarray(p2["tok_emb"]))
        assert np.array_equal(np.asarray(p1["layers"][0]["wq"]["qs"]),
                              np.asarray(p2["layers"][0]["wq"]["qs"]))
