"""Q4_0 quantizer unit + property tests (ggml-compatible layout)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    QK4_0,
    dequantize_q4_0,
    pack_q4_0_bytes,
    quantize_q4_0,
    unpack_q4_0_bytes,
)


def rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


class TestQuantizeShape:
    def test_output_shapes(self):
        qs, d = quantize_q4_0(rand((8, 96)))
        assert qs.shape == (8, 3, 16) and qs.dtype == np.uint8
        assert d.shape == (8, 3) and d.dtype == np.float16

    def test_rejects_bad_k(self):
        with pytest.raises(ValueError):
            quantize_q4_0(rand((4, 33)))

    def test_1d_input(self):
        qs, d = quantize_q4_0(rand((64,)))
        assert qs.shape == (2, 16) and d.shape == (2,)


class TestQuantizeNumerics:
    def test_roundtrip_error_bound(self):
        """Error per element is bounded by one quantization step.

        Q4_0's codebook spans [-8d, +7d]: values near +8|d| clamp to the
        +7d code, so the worst case is a full step (not half)."""
        x = rand((16, 256), seed=1)
        qs, d = quantize_q4_0(x)
        y = dequantize_q4_0(qs, d)
        step = np.abs(d.astype(np.float32))[..., None]
        err = np.abs((x - y).reshape(16, -1, QK4_0))
        assert np.all(err <= step * 1.0 + 1e-6)

    def test_zeros_block(self):
        qs, d = quantize_q4_0(np.zeros((1, 32), np.float32))
        assert np.all(d == 0)
        assert np.allclose(dequantize_q4_0(qs, d), 0)

    def test_extreme_negative_maps_to_zero_nibble(self):
        """ggml rule: the max-|x| value defines the scale as max/-8."""
        x = np.zeros((1, 32), np.float32)
        x[0, 5] = -16.0
        qs, d = quantize_q4_0(x)
        assert np.isclose(float(d[0, 0]), 2.0)  # -16 / -8
        y = dequantize_q4_0(qs, d)
        assert np.isclose(y[0, 5], -16.0)

    def test_positive_max_gives_negative_scale(self):
        x = np.zeros((1, 32), np.float32)
        x[0, 0] = 8.0
        qs, d = quantize_q4_0(x)
        assert float(d[0, 0]) == -1.0
        assert np.isclose(dequantize_q4_0(qs, d)[0, 0], 8.0)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 8),
           st.sampled_from([32, 64, 160]),
           st.floats(1e-3, 1e3))
    def test_roundtrip_property(self, seed, n, k, scale):
        x = rand((n, k), seed=seed, scale=scale)
        qs, d = quantize_q4_0(x)
        y = dequantize_q4_0(qs, d)
        step = np.abs(d.astype(np.float32))[..., None]
        err = np.abs((x - y).reshape(n, -1, QK4_0))
        # one step (asymmetric codebook) plus f16 rounding slack
        assert np.all(err <= step * 1.0 + np.abs(step) * 1e-2 + 1e-6)


class TestPackBytes:
    def test_block_stream_layout(self):
        """Per block: 2-byte f16 scale then 16 nibble bytes (18 total)."""
        x = rand((2, 64), seed=2)
        qs, d = quantize_q4_0(x)
        raw = pack_q4_0_bytes(qs, d)
        assert len(raw) == 2 * 2 * 18
        first_scale = np.frombuffer(raw[:2], "<f2")[0]
        assert first_scale == d[0, 0]
        assert raw[2:18] == qs[0, 0].tobytes()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31), st.integers(1, 6), st.sampled_from([32, 96]))
    def test_pack_unpack_roundtrip(self, seed, n, k):
        x = rand((n, k), seed=seed)
        qs, d = quantize_q4_0(x)
        qs2, d2 = unpack_q4_0_bytes(pack_q4_0_bytes(qs, d), n, k)
        assert np.array_equal(qs, qs2)
        assert np.array_equal(d, d2)
