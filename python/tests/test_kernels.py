"""Layer-1 Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and tile parameters; every comparison is an
``assert_allclose`` against the reference implementation.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.q4gemm import q4_gemm
from compile.kernels.rmsnorm import rmsnorm
from compile.quantize import quantize_q4_0


def _qweights(n, k, seed=0, scale=1.0):
    w = (np.random.default_rng(seed).standard_normal((n, k)) * scale).astype(np.float32)
    qs, d = quantize_q4_0(w)
    return jnp.asarray(qs), jnp.asarray(d.astype(np.float32))


def _x(m, k, seed=1):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((m, k)).astype(np.float32))


class TestQ4Gemm:
    def test_basic(self):
        x = _x(4, 128)
        qs, d = _qweights(96, 128)
        assert_allclose(np.asarray(q4_gemm(x, qs, d)),
                        np.asarray(ref.q4_gemm(x, qs, d)), rtol=1e-5, atol=1e-4)

    def test_gemv_decode_shape(self):
        """M=1 is the decode hot path."""
        x = _x(1, 256)
        qs, d = _qweights(64, 256)
        assert_allclose(np.asarray(q4_gemm(x, qs, d)),
                        np.asarray(ref.q4_gemm(x, qs, d)), rtol=1e-5, atol=1e-4)

    def test_k_accumulation_across_grid(self):
        """K larger than block_k exercises the K-grid accumulate path."""
        x = _x(2, 1024)
        qs, d = _qweights(32, 1024)
        out = q4_gemm(x, qs, d, block_k=256)
        assert_allclose(np.asarray(out), np.asarray(ref.q4_gemm(x, qs, d)),
                        rtol=1e-5, atol=1e-3)

    @settings(max_examples=12, deadline=None)
    @given(
        m=st.sampled_from([1, 3, 8]),
        n=st.sampled_from([16, 64, 96]),
        k=st.sampled_from([32, 128, 320]),
        bm=st.sampled_from([2, 8]),
        bn=st.sampled_from([16, 64]),
        bk=st.sampled_from([32, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_property_tiles(self, m, n, k, bm, bn, bk, seed):
        x = _x(m, k, seed=seed)
        qs, d = _qweights(n, k, seed=seed + 1)
        out = q4_gemm(x, qs, d, block_m=bm, block_n=bn, block_k=bk)
        assert_allclose(np.asarray(out), np.asarray(ref.q4_gemm(x, qs, d)),
                        rtol=1e-4, atol=1e-3)

    def test_scale_extremes(self):
        qs, d = _qweights(32, 64, scale=1e-4)
        x = _x(2, 64)
        assert_allclose(np.asarray(q4_gemm(x, qs, d)),
                        np.asarray(ref.q4_gemm(x, qs, d)), rtol=1e-4, atol=1e-7)


class TestAttention:
    def _qkv(self, h, tq, tk, d, seed=0):
        rng = np.random.default_rng(seed)
        return (jnp.asarray(rng.standard_normal((h, tq, d)).astype(np.float32)),
                jnp.asarray(rng.standard_normal((h, tk, d)).astype(np.float32)),
                jnp.asarray(rng.standard_normal((h, tk, d)).astype(np.float32)))

    def test_noncausal(self):
        q, k, v = self._qkv(4, 8, 64, 16)
        assert_allclose(np.asarray(attention(q, k, v, causal=False, block_k=16)),
                        np.asarray(ref.attention(q, k, v, causal=False)),
                        rtol=1e-5, atol=1e-5)

    def test_causal_prefill(self):
        q, k, v = self._qkv(2, 32, 32, 8, seed=3)
        assert_allclose(np.asarray(attention(q, k, v, causal=True, q_offset=0, block_k=8)),
                        np.asarray(ref.attention(q, k, v, causal=True)),
                        rtol=1e-5, atol=1e-5)

    def test_decode_single_row(self):
        q, k, v = self._qkv(4, 1, 64, 16, seed=4)
        assert_allclose(
            np.asarray(attention(q, k, v, causal=True, q_offset=63, block_k=16)),
            np.asarray(ref.attention(q, k, v, causal=True, q_offset=63)),
            rtol=1e-5, atol=1e-5)

    def test_garbage_beyond_position_is_masked(self):
        """Cache slots past the current position must not leak in."""
        q, k, v = self._qkv(2, 1, 32, 8, seed=5)
        k = k.at[:, 10:].set(1e5)
        v = v.at[:, 10:].set(1e5)
        out = attention(q, k, v, causal=True, q_offset=9, block_k=8)
        expect = ref.attention(q[:, :, :], k[:, :10], v[:, :10],
                               causal=True, q_offset=9)
        assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        h=st.sampled_from([1, 4]),
        tq=st.sampled_from([1, 5, 16]),
        tk=st.sampled_from([16, 48]),
        dim=st.sampled_from([8, 32]),
        bk=st.sampled_from([8, 16, 48]),
        seed=st.integers(0, 2**31),
    )
    def test_property(self, h, tq, tk, dim, bk, seed):
        q, k, v = self._qkv(h, tq, tk, dim, seed=seed)
        off = tk - tq
        assert_allclose(
            np.asarray(attention(q, k, v, causal=True, q_offset=off, block_k=bk)),
            np.asarray(ref.attention(q, k, v, causal=True, q_offset=off)),
            rtol=1e-4, atol=1e-4)


class TestRmsNorm:
    def test_2d(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((7, 96)).astype(np.float32))
        g = jnp.asarray(rng.standard_normal(96).astype(np.float32))
        assert_allclose(np.asarray(rmsnorm(x, g)), np.asarray(ref.rmsnorm(x, g)),
                        rtol=1e-5, atol=1e-6)

    def test_1d(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal(64).astype(np.float32))
        g = jnp.asarray(np.ones(64, np.float32))
        out = rmsnorm(x, g)
        assert out.shape == (64,)
        assert_allclose(np.asarray(out), np.asarray(ref.rmsnorm(x, g)),
                        rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(t=st.sampled_from([1, 4, 33]), d=st.sampled_from([16, 64, 200]),
           seed=st.integers(0, 2**31))
    def test_property(self, t, d, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
        g = jnp.asarray(rng.standard_normal(d).astype(np.float32))
        assert_allclose(np.asarray(rmsnorm(x, g)), np.asarray(ref.rmsnorm(x, g)),
                        rtol=1e-4, atol=1e-5)
